//! Typed configuration system with JSON overlay loading.
//!
//! Everything tunable lives here with documented defaults; a JSON config
//! file (`--config path`) overrides fields selectively. The four operator
//! profiles of the paper ((α, λ, μ) preference weights) are first-class
//! values.

use anyhow::Result;

use crate::backend::kv_cache::PrefixCacheConfig;
use crate::util::json::Json;

/// Non-negative preference parameters (α, λ, μ) of the orchestration
/// objective — normalized into convex weights by [`crate::scoring`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Profile {
    pub name: &'static str,
    /// α — model quality / relevance preference.
    pub alpha: f64,
    /// λ — latency preference.
    pub lambda: f64,
    /// μ — resource-cost preference.
    pub mu: f64,
}

impl Profile {
    /// The paper's four operator profiles plus the unrouted baseline.
    pub const BASELINE: Profile =
        Profile { name: "baseline", alpha: 0.0, lambda: 0.0, mu: 0.0 };
    pub const QUALITY: Profile =
        Profile { name: "quality", alpha: 1.0, lambda: 0.1, mu: 0.1 };
    pub const COST: Profile =
        Profile { name: "cost", alpha: 0.3, lambda: 0.2, mu: 0.8 };
    pub const SPEED: Profile =
        Profile { name: "speed", alpha: 0.3, lambda: 0.8, mu: 0.2 };
    pub const BALANCED: Profile =
        Profile { name: "balanced", alpha: 0.5, lambda: 0.3, mu: 0.3 };

    pub const ALL: [Profile; 5] = [
        Profile::BASELINE,
        Profile::QUALITY,
        Profile::COST,
        Profile::SPEED,
        Profile::BALANCED,
    ];

    pub fn by_name(name: &str) -> Option<Profile> {
        Profile::ALL.iter().copied().find(|p| p.name == name)
    }
}

/// Router operating mode (paper: keyword, DistilBERT, hybrid).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouterMode {
    Keyword,
    Semantic,
    Hybrid,
}

impl RouterMode {
    pub fn parse(s: &str) -> Option<RouterMode> {
        match s {
            "keyword" => Some(RouterMode::Keyword),
            "semantic" | "distilbert" => Some(RouterMode::Semantic),
            "hybrid" => Some(RouterMode::Hybrid),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            RouterMode::Keyword => "keyword",
            RouterMode::Semantic => "semantic",
            RouterMode::Hybrid => "hybrid",
        }
    }
}

/// Router tunables.
#[derive(Debug, Clone)]
pub struct RouterConfig {
    pub mode: RouterMode,
    /// Hybrid: below this keyword-confidence the semantic path refines.
    pub hybrid_confidence: f64,
    /// Semantic classification overhead added per query (paper: the
    /// DistilBERT step costs extra latency; measured live, simulated in
    /// sim mode).
    pub semantic_overhead_s: f64,
}

impl Default for RouterConfig {
    fn default() -> Self {
        Self {
            mode: RouterMode::Hybrid,
            hybrid_confidence: 0.65,
            semantic_overhead_s: 0.35,
        }
    }
}

/// Spin (Algorithm 1) tunables.
#[derive(Debug, Clone)]
pub struct OrchestratorConfig {
    /// Telemetry window w (paper: 5 min).
    pub telemetry_window_s: f64,
    /// Per-replica target concurrency (Little's-law divisor).
    pub target_concurrency: f64,
    /// Idle threshold τ before scale-down.
    pub idle_timeout_s: f64,
    /// Cooldown between scale-ups (prevents oscillation).
    pub cooldown_s: f64,
    /// Warm-pool size per tier index [small, medium, large].
    pub warm_pool: [usize; 3],
    /// Hard replica cap per service.
    pub max_replicas: usize,
    /// Health-check period.
    pub health_period_s: f64,
}

impl Default for OrchestratorConfig {
    fn default() -> Self {
        Self {
            telemetry_window_s: 300.0,
            target_concurrency: 4.0,
            idle_timeout_s: 120.0,
            cooldown_s: 30.0,
            warm_pool: [1, 1, 0],
            max_replicas: 8,
            health_period_s: 5.0,
        }
    }
}

/// Gateway tunables.
#[derive(Debug, Clone)]
pub struct GatewayConfig {
    pub port: u16,
    pub queue_capacity: usize,
    pub worker_threads: usize,
    pub request_timeout_s: f64,
}

impl Default for GatewayConfig {
    fn default() -> Self {
        Self {
            port: 8080,
            queue_capacity: 1024,
            worker_threads: 8,
            request_timeout_s: 120.0,
        }
    }
}

/// Which runtime substrate the live gateway provisions replicas on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubstrateKind {
    /// Replica = one engine thread inside the gateway process (shared
    /// memory data plane; a hard crash takes the whole pool down).
    Thread,
    /// Replica = one supervised `ps-replica` OS process, connected over
    /// a framed JSON RPC channel on a Unix socket (real isolation:
    /// `kill -9` on a worker is survivable — the paper's pod-per-replica
    /// deployment model, one host at a time).
    Process,
}

impl SubstrateKind {
    pub fn parse(s: &str) -> Option<SubstrateKind> {
        match s {
            "thread" => Some(SubstrateKind::Thread),
            "process" => Some(SubstrateKind::Process),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            SubstrateKind::Thread => "thread",
            SubstrateKind::Process => "process",
        }
    }
}

/// How the process substrate spreads a tier's replicas across the
/// registered node agents.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Placement {
    /// Least-loaded with tier anti-affinity: prefer the node hosting the
    /// fewest replicas of this tier, tie-broken by fewest total replicas
    /// — one node dying takes out at most one replica of each tier.
    #[default]
    Spread,
    /// Fill the lowest-numbered node before touching the next (bin
    /// packing; frees whole nodes for scale-down).
    Pack,
}

impl Placement {
    pub fn parse(s: &str) -> Option<Placement> {
        match s {
            "spread" => Some(Placement::Spread),
            "pack" => Some(Placement::Pack),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Placement::Spread => "spread",
            Placement::Pack => "pack",
        }
    }
}

/// Multi-host node plane for the process substrate (`pool.nodes.*`).
/// Unset (the default) keeps every replica a local child process —
/// exactly the single-host behavior the substrate shipped with.
#[derive(Debug, Clone, Default)]
pub struct NodesConfig {
    /// TCP address the supervisor listens on for inbound `ps-node`
    /// registrations (e.g. `"0.0.0.0:7070"`). Its host part is also the
    /// bind host for per-replica data listeners (must be reachable from
    /// the nodes). `None` = no listener.
    pub listen_addr: Option<String>,
    /// `host:port` addresses of `ps-node --listen` agents the supervisor
    /// dials at startup (registration is the same handshake in either
    /// direction; an unreachable agent is a startup error).
    pub agents: Vec<String>,
    /// Replica placement policy across registered nodes.
    pub placement: Placement,
}

impl NodesConfig {
    /// Whether a node plane is configured at all.
    pub fn configured(&self) -> bool {
        self.listen_addr.is_some() || !self.agents.is_empty()
    }
}

/// Cache-affinity dispatch (`pool.affinity.*`): route each request to
/// the replica whose advertised hot-prefix summary shares the longest
/// chained block-hash prefix with the prompt, instead of blind per-tier
/// fan-out. Off by default — disabled reproduces the exact legacy
/// round-robin queue behavior bit-for-bit.
#[derive(Debug, Clone)]
pub struct AffinityConfig {
    /// Master switch. `false` = legacy tier-queue fan-out, no summaries
    /// consulted, no transfers brokered.
    pub enabled: bool,
    /// How many hot prefix chain tips each replica advertises per
    /// heartbeat (top-K by recency).
    pub top_k: usize,
    /// Minimum matched chain length (in KV blocks) before the router
    /// prefers a replica over the least-loaded fallback.
    pub min_match_blocks: usize,
    /// Broker cross-replica KV block transfer: when a request routes to
    /// a cold replica but a peer advertises its prefix, pull the cached
    /// blocks over the RPC plane instead of recomputing them.
    pub transfer: bool,
}

impl Default for AffinityConfig {
    fn default() -> Self {
        Self { enabled: false, top_k: 8, min_match_blocks: 1, transfer: true }
    }
}

/// Cross-tier speculative decoding (`pool.speculative.*`): a small tier
/// drafts a window of tokens that a bigger tier's engine verifies in one
/// batched step, landing the longest accepted prefix plus one correction
/// token per step. Off by default — disabled reproduces the exact
/// plain-decode scheduling bit-for-bit. `Copy` so it rides inside
/// `SchedulerConfig`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SpeculativeConfig {
    /// Master switch. `false` = plain decode everywhere, no draft
    /// windows, no verify steps, no rollback.
    pub enabled: bool,
    /// Tier index that drafts (0 = small). Pairing rule: only tiers
    /// strictly *above* this one speculate; the draft tier itself (and
    /// anything below it) always decodes plainly.
    pub draft_tier: usize,
    /// Draft window k: tokens drafted per verify step. Each verify step
    /// lands between 1 (all rejected → correction only) and k + 1 (all
    /// accepted + bonus) tokens.
    pub draft_tokens: usize,
    /// Auto-disable floor: a verify-side scheduler whose EMA acceptance
    /// rate drops below this (after a short warmup) stops speculating —
    /// low-acceptance workloads must not pay verify overhead forever.
    pub min_accept_rate: f64,
    /// Acceptance-rate model for the synthetic (sim) engines: the
    /// probability each draft token matches the verify model's choice.
    /// Only the *timing* is modeled — token streams stay bit-identical
    /// to plain decode. Ignored on the compiled path.
    pub sim_accept: f64,
}

impl SpeculativeConfig {
    /// The inert configuration (also the `Default`).
    pub fn disabled() -> SpeculativeConfig {
        SpeculativeConfig::default()
    }

    /// Whether `verify_tier` pairs with the configured draft tier: the
    /// draft tier must sit strictly below it on the ladder.
    pub fn pairs_with(&self, verify_tier: usize) -> bool {
        self.enabled && self.draft_tier < verify_tier && verify_tier < 3
    }
}

impl Default for SpeculativeConfig {
    fn default() -> Self {
        Self {
            enabled: false,
            draft_tier: 0,
            draft_tokens: 4,
            min_accept_rate: 0.3,
            sim_accept: 0.75,
        }
    }
}

/// Request priority class for admission control and weighted-fair
/// dequeue. Lower index = more latency-sensitive; under overload the
/// gateway sheds from the *highest* index (batch) first.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Priority {
    /// Latency-sensitive interactive traffic — shed last.
    Interactive,
    /// The default class for unlabelled requests.
    #[default]
    Standard,
    /// Throughput work that tolerates deferral — shed first.
    Batch,
}

impl Priority {
    pub const ALL: [Priority; 3] =
        [Priority::Interactive, Priority::Standard, Priority::Batch];

    pub fn parse(s: &str) -> Option<Priority> {
        match s {
            "interactive" => Some(Priority::Interactive),
            "standard" => Some(Priority::Standard),
            "batch" => Some(Priority::Batch),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Priority::Interactive => "interactive",
            Priority::Standard => "standard",
            Priority::Batch => "batch",
        }
    }

    pub fn index(self) -> usize {
        match self {
            Priority::Interactive => 0,
            Priority::Standard => 1,
            Priority::Batch => 2,
        }
    }

    pub fn from_index(i: usize) -> Priority {
        Priority::ALL[i.min(2)]
    }
}

/// Overload admission control (`pool.admission.*`): router-side priority
/// buffers with weighted-fair dequeue, queue-depth watermark shedding of
/// the lowest priority class, and deadline-feasibility rejection from
/// the measured per-tier drain rate. Off by default — disabled
/// reproduces the exact direct tier-queue dispatch bit-for-bit.
#[derive(Debug, Clone)]
pub struct AdmissionConfig {
    /// Master switch. `false` = jobs go straight to the tier queues,
    /// no priority buffers, no shedding, no feasibility checks.
    pub enabled: bool,
    /// Shed watermark as a fraction of `pool.queue_capacity`: once a
    /// tier's backlog (queue + priority buffers) passes this, the
    /// lowest-priority buffered work is shed with 429 + Retry-After.
    pub watermark: f64,
    /// Weighted-fair dequeue weights `[interactive, standard, batch]`:
    /// per scheduling round, how many jobs each class may dispatch
    /// before yielding to the next class.
    pub weights: [usize; 3],
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        Self { enabled: false, watermark: 0.75, weights: [4, 2, 1] }
    }
}

/// Per-request tracing (`pool.trace.*`): typed spans across router →
/// wire → scheduler, the `/debug/traces` flight recorder, and the
/// `ps_span_seconds` latency-breakdown histograms. Off by default —
/// disabled reproduces the untraced dispatch (wire frames included)
/// bit-for-bit.
#[derive(Debug, Clone)]
pub struct TraceConfig {
    /// Master switch. `false` = no trace contexts are minted, jobs carry
    /// a null trace pointer, and wire frames omit every trace field.
    pub enabled: bool,
    /// Flight-recorder capacity: how many completed traces
    /// `/debug/traces` retains (newest-first ring).
    pub ring_size: usize,
    /// Fraction of requests traced in [0, 1]. Sampling only gates trace
    /// *recording* — never the token stream — and is deterministic in
    /// the trace id. Requests arriving with a `traceparent` header are
    /// always traced.
    pub sample_rate: f64,
    /// Structured one-line JSON access log per completed/failed request,
    /// written through a buffered non-blocking writer. `""` (default) =
    /// off; `"stderr"` = the gateway's stderr; anything else = a file
    /// path appended to.
    pub access_log: String,
}

impl Default for TraceConfig {
    fn default() -> Self {
        Self {
            enabled: false,
            ring_size: crate::telemetry::trace::DEFAULT_RING_SIZE,
            sample_rate: 1.0,
            access_log: String::new(),
        }
    }
}

/// Learned routing (`pool.routing.*`): feedback-driven policies layered
/// over the static classifier + Alg. 2 selection. Off by default —
/// disabled reproduces the exact static routing decisions bit-for-bit.
#[derive(Debug, Clone, Default)]
pub struct RoutingConfig {
    /// Online contextual bandit over (complexity class, tier) arms.
    pub bandit: BanditConfig,
}

/// Contextual-bandit tier selection (`pool.routing.bandit.*`): per
/// (complexity-class, tier) running estimates of success, latency, and
/// cost learned from completed-request outcomes, selecting tiers via an
/// epsilon-greedy/UCB policy. Off by default — the static router's
/// choice always stands when disabled.
#[derive(Debug, Clone)]
pub struct BanditConfig {
    /// Master switch. `false` = static routing only: no arms, no RNG
    /// draws, no feedback, token-identical legacy behavior.
    pub enabled: bool,
    /// Exploration rate: fraction of selections routed to a uniformly
    /// random eligible tier once every arm has `min_samples` pulls.
    pub epsilon: f64,
    /// Rolling window (samples) for each arm's reward/latency/cost
    /// estimates — old outcomes age out so the learner tracks drift.
    pub window: usize,
    /// Forced-exploration floor: arms with fewer pulls than this are
    /// tried first (round-robin) before the greedy/UCB policy engages.
    pub min_samples: usize,
}

impl Default for BanditConfig {
    fn default() -> Self {
        Self { enabled: false, epsilon: 0.05, window: 256, min_samples: 10 }
    }
}

/// Tier-name → tier-index for chain route parsing (mirrors
/// `models::Tier::name` without a dependency edge).
fn chain_tier_index(s: &str) -> Option<usize> {
    match s {
        "small" => Some(0),
        "medium" => Some(1),
        "large" => Some(2),
        _ => None,
    }
}

/// Per-route fallback chains (`pool.chains.*`): when a completion on an
/// origin tier fails, times out, or scores below the floor, the gateway
/// re-dispatches it along the configured escalation route (bigger
/// tiers), degrading to a smaller tier instead when the target is
/// saturated — all under a per-request hop budget with exponential
/// backoff and a gateway-wide retry-budget ratio so retries can never
/// amplify an outage. Empty routes (the default) reproduce the exact
/// single-dispatch behavior bit-for-bit.
#[derive(Debug, Clone)]
pub struct ChainsConfig {
    /// Ordered escalation targets per origin tier index (e.g.
    /// `routes[0] = [1, 2]`: small escalates to medium then large).
    /// Empty = no chain for that origin tier.
    pub routes: [Vec<usize>; 3],
    /// Per-request hop budget: total escalate/degrade re-dispatches one
    /// request may consume after its first attempt.
    pub max_retries: usize,
    /// Exponential backoff base between hops (hop n waits
    /// `backoff_base_s * 2^n`).
    pub backoff_base_s: f64,
    /// Gateway-wide retry budget: chain re-dispatches are forfeited
    /// (the request fails with its last error) once issued retries
    /// would exceed this fraction of fresh traffic.
    pub retry_budget_ratio: f64,
    /// Relevance floor: a successful completion whose tier relevance
    /// score (`scoring::relevance`) falls below this escalates anyway.
    /// `0.0` (default) never triggers on success.
    pub score_floor: f64,
    /// Permit degrading to a smaller tier when every escalation target
    /// is saturated (queue full).
    pub degrade: bool,
}

impl ChainsConfig {
    /// Whether any route is configured at all.
    pub fn any(&self) -> bool {
        self.routes.iter().any(|r| !r.is_empty())
    }
}

impl Default for ChainsConfig {
    fn default() -> Self {
        Self {
            routes: [Vec::new(), Vec::new(), Vec::new()],
            max_retries: 2,
            backoff_base_s: 0.05,
            retry_budget_ratio: 0.1,
            score_floor: 0.0,
            degrade: true,
        }
    }
}

/// Engine-pool tunables: the continuous-batching serving path
/// (gateway job intake → per-tier scheduler → N engine replicas).
#[derive(Debug, Clone)]
pub struct PoolConfig {
    /// Engine replicas per tier index [small, medium, large]. Each
    /// replica is one engine thread owning its own compiled engines.
    pub replicas: [usize; 3],
    /// Decode slots per replica (max in-flight sequences sharing one
    /// engine's interleaved decode loop).
    pub max_inflight: usize,
    /// Per-tier queue bound between the router and the replicas
    /// (admission control: beyond this, requests are rejected).
    pub queue_capacity: usize,
    /// Largest decode batch the scheduler may form (≤ largest compiled).
    pub max_decode_batch: usize,
    /// Largest prefill batch the scheduler may form (≤ largest compiled
    /// prefill rung): admissions buffer briefly so prefill dispatches at
    /// ladder rungs instead of serially per sequence.
    pub max_prefill_batch: usize,
    /// How long a partial batch may wait for batch-mates before it runs.
    pub flush_timeout_s: f64,
    /// Paged-KV pool per replica: block count × tokens per block bounds
    /// admitted work (reservation-based, no mid-flight OOM).
    pub kv_blocks: usize,
    pub kv_block_tokens: usize,
    /// Radix prefix cache over the paged pool (`pool.prefix_cache.*`):
    /// shared prompt prefixes are refcounted across sequences, admission
    /// charges only the uncached suffix, and unreferenced blocks evict
    /// LRU past the watermark. On by default; disabling restores the
    /// exact full-reservation accounting.
    pub prefix_cache: PrefixCacheConfig,
    /// Cache-affinity routing + cross-replica KV transfer
    /// (`pool.affinity.*`). Off by default.
    pub affinity: AffinityConfig,
    /// Cross-tier speculative decoding (`pool.speculative.*`): small-tier
    /// drafts, big-tier batched verify. Off by default.
    pub speculative: SpeculativeConfig,
    /// Overload admission control (`pool.admission.*`): priority
    /// buffers, watermark shedding, deadline feasibility. Off by
    /// default.
    pub admission: AdmissionConfig,
    /// Per-route fallback chains (`pool.chains.*`): escalate/degrade
    /// re-dispatch under bounded retry budgets. Empty by default.
    pub chains: ChainsConfig,
    /// Per-request tracing (`pool.trace.*`): spans, flight recorder,
    /// latency-breakdown histograms, access log. Off by default.
    pub trace: TraceConfig,
    /// Learned routing (`pool.routing.*`): contextual-bandit tier
    /// selection fed by completed-request outcomes. Off by default.
    pub routing: RoutingConfig,
    /// How often the pool scaler re-plans per-tier active replicas from
    /// queue depth + slot occupancy.
    pub scale_interval_s: f64,
    /// Replica health deadline: a Ready replica thread whose heartbeat
    /// goes stale past this is declared Failed (stalled engine) and
    /// redeployed by the recovery manager.
    pub health_deadline_s: f64,
    /// Replica runtime: in-process engine threads (`"thread"`) or
    /// supervised `ps-replica` worker processes over the RPC data plane
    /// (`"process"`).
    pub substrate: SubstrateKind,
    /// Worker binary for the process substrate. `None` = the current
    /// executable (the gateway binary re-invokes itself in `ps-replica`
    /// mode); tests point this at `CARGO_BIN_EXE_pick-and-spin`.
    pub worker_bin: Option<String>,
    /// Where worker processes write their stdout/stderr logs (one
    /// `ps-worker-<tier>-<replica>-<pid>-<seq>.log` per replica; the
    /// pid + sequence keep names unique across supervisor instances).
    /// `None` = inherit the gateway's stderr. CI sets this and uploads
    /// the directory.
    pub worker_log_dir: Option<String>,
    /// Multi-host node plane (process substrate only): where node agents
    /// register and how replicas place across them. Unconfigured =
    /// local spawn, today's single-host behavior.
    pub nodes: NodesConfig,
}

impl Default for PoolConfig {
    fn default() -> Self {
        Self {
            replicas: [1, 1, 1],
            max_inflight: 8,
            queue_capacity: 256,
            max_decode_batch: 8,
            max_prefill_batch: 4,
            flush_timeout_s: 0.020,
            kv_blocks: 128,
            kv_block_tokens: 16,
            prefix_cache: PrefixCacheConfig::default(),
            affinity: AffinityConfig::default(),
            speculative: SpeculativeConfig::default(),
            admission: AdmissionConfig::default(),
            chains: ChainsConfig::default(),
            trace: TraceConfig::default(),
            routing: RoutingConfig::default(),
            scale_interval_s: 2.0,
            health_deadline_s: 3.0,
            substrate: SubstrateKind::Thread,
            worker_bin: None,
            worker_log_dir: None,
            nodes: NodesConfig::default(),
        }
    }
}

/// Cluster-substrate constants (the simulated Kubernetes behaviour).
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// GPUs per node.
    pub gpus_per_node: usize,
    /// Number of nodes.
    pub nodes: usize,
    /// Container image pull time (cold / cached).
    pub image_pull_cold_s: f64,
    pub image_pull_cached_s: f64,
    /// PVC read bandwidth for weight loading (GB/s).
    pub pvc_bandwidth_gbps: f64,
    /// Engine initialization time after weights are resident.
    pub engine_init_s: f64,
    /// Pod failure rate (failures per pod-hour) for recovery experiments.
    pub failure_rate_per_hour: f64,
    /// Scheduler tick.
    pub tick_s: f64,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        Self {
            gpus_per_node: 8,
            nodes: 4,
            image_pull_cold_s: 12.0,
            image_pull_cached_s: 1.0,
            pvc_bandwidth_gbps: 2.0,
            engine_init_s: 3.0,
            failure_rate_per_hour: 0.0,
            tick_s: 1.0,
        }
    }
}

/// Paths to build artifacts and shared data.
#[derive(Debug, Clone)]
pub struct Paths {
    pub artifacts: String,
    pub data: String,
}

impl Default for Paths {
    fn default() -> Self {
        Self { artifacts: "artifacts".into(), data: "data".into() }
    }
}

/// Top-level configuration.
#[derive(Debug, Clone, Default)]
pub struct Config {
    pub paths: Paths,
    pub router: RouterConfig,
    pub orchestrator: OrchestratorConfig,
    pub gateway: GatewayConfig,
    pub pool: PoolConfig,
    pub cluster: ClusterConfig,
    pub profile: Profile,
}

impl Default for Profile {
    fn default() -> Self {
        Profile::BALANCED
    }
}

impl Config {
    /// Load defaults, then overlay a JSON file if given.
    pub fn load(path: Option<&str>) -> Result<Config> {
        let mut cfg = Config::default();
        if let Some(p) = path {
            cfg.overlay(&Json::from_file(p)?)?;
        }
        Ok(cfg)
    }

    /// Apply a JSON overlay (partial — only present keys override).
    pub fn overlay(&mut self, j: &Json) -> Result<()> {
        if let Some(p) = j.get("paths") {
            self.paths.artifacts =
                p.str_or("artifacts", &self.paths.artifacts).to_string();
            self.paths.data = p.str_or("data", &self.paths.data).to_string();
        }
        if let Some(r) = j.get("router") {
            if let Some(m) = r.get("mode").and_then(Json::as_str) {
                self.router.mode = RouterMode::parse(m)
                    .ok_or_else(|| anyhow::anyhow!("bad router mode `{m}`"))?;
            }
            self.router.hybrid_confidence =
                r.f64_or("hybrid_confidence", self.router.hybrid_confidence);
            self.router.semantic_overhead_s =
                r.f64_or("semantic_overhead_s", self.router.semantic_overhead_s);
        }
        if let Some(o) = j.get("orchestrator") {
            self.orchestrator.telemetry_window_s =
                o.f64_or("telemetry_window_s", self.orchestrator.telemetry_window_s);
            self.orchestrator.target_concurrency =
                o.f64_or("target_concurrency", self.orchestrator.target_concurrency);
            self.orchestrator.idle_timeout_s =
                o.f64_or("idle_timeout_s", self.orchestrator.idle_timeout_s);
            self.orchestrator.cooldown_s =
                o.f64_or("cooldown_s", self.orchestrator.cooldown_s);
            self.orchestrator.max_replicas =
                o.usize_or("max_replicas", self.orchestrator.max_replicas);
            if let Some(w) = o.get("warm_pool").and_then(Json::as_arr) {
                for (i, v) in w.iter().take(3).enumerate() {
                    if let Some(n) = v.as_usize() {
                        self.orchestrator.warm_pool[i] = n;
                    }
                }
            }
        }
        if let Some(g) = j.get("gateway") {
            self.gateway.port = g.usize_or("port", self.gateway.port as usize) as u16;
            self.gateway.queue_capacity =
                g.usize_or("queue_capacity", self.gateway.queue_capacity);
            self.gateway.worker_threads =
                g.usize_or("worker_threads", self.gateway.worker_threads);
            self.gateway.request_timeout_s =
                g.f64_or("request_timeout_s", self.gateway.request_timeout_s);
        }
        if let Some(p) = j.get("pool") {
            if let Some(r) = p.get("replicas").and_then(Json::as_arr) {
                for (i, v) in r.iter().take(3).enumerate() {
                    if let Some(n) = v.as_usize() {
                        self.pool.replicas[i] = n;
                    }
                }
            }
            self.pool.max_inflight =
                p.usize_or("max_inflight", self.pool.max_inflight);
            self.pool.queue_capacity =
                p.usize_or("queue_capacity", self.pool.queue_capacity);
            self.pool.max_decode_batch =
                p.usize_or("max_decode_batch", self.pool.max_decode_batch);
            self.pool.max_prefill_batch =
                p.usize_or("max_prefill_batch", self.pool.max_prefill_batch);
            self.pool.flush_timeout_s =
                p.f64_or("flush_timeout_s", self.pool.flush_timeout_s);
            self.pool.kv_blocks = p.usize_or("kv_blocks", self.pool.kv_blocks);
            self.pool.kv_block_tokens =
                p.usize_or("kv_block_tokens", self.pool.kv_block_tokens);
            if let Some(pc) = p.get("prefix_cache") {
                self.pool.prefix_cache.enabled =
                    pc.bool_or("enabled", self.pool.prefix_cache.enabled);
                self.pool.prefix_cache.min_block_run = pc
                    .usize_or("min_block_run", self.pool.prefix_cache.min_block_run);
                self.pool.prefix_cache.evict_watermark = pc
                    .f64_or("evict_watermark", self.pool.prefix_cache.evict_watermark);
            }
            if let Some(a) = p.get("affinity") {
                self.pool.affinity.enabled =
                    a.bool_or("enabled", self.pool.affinity.enabled);
                self.pool.affinity.top_k =
                    a.usize_or("top_k", self.pool.affinity.top_k);
                self.pool.affinity.min_match_blocks = a
                    .usize_or("min_match_blocks", self.pool.affinity.min_match_blocks);
                self.pool.affinity.transfer =
                    a.bool_or("transfer", self.pool.affinity.transfer);
            }
            if let Some(s) = p.get("speculative") {
                self.pool.speculative.enabled =
                    s.bool_or("enabled", self.pool.speculative.enabled);
                self.pool.speculative.draft_tier =
                    s.usize_or("draft_tier", self.pool.speculative.draft_tier);
                self.pool.speculative.draft_tokens =
                    s.usize_or("draft_tokens", self.pool.speculative.draft_tokens);
                self.pool.speculative.min_accept_rate = s
                    .f64_or("min_accept_rate", self.pool.speculative.min_accept_rate);
                self.pool.speculative.sim_accept =
                    s.f64_or("sim_accept", self.pool.speculative.sim_accept);
            }
            if let Some(a) = p.get("admission") {
                self.pool.admission.enabled =
                    a.bool_or("enabled", self.pool.admission.enabled);
                self.pool.admission.watermark =
                    a.f64_or("watermark", self.pool.admission.watermark);
                if let Some(w) = a.get("weights") {
                    let arr = w.as_arr().ok_or_else(|| {
                        anyhow::anyhow!("pool.admission.weights must be an array")
                    })?;
                    for (i, v) in arr.iter().take(3).enumerate() {
                        self.pool.admission.weights[i] =
                            v.as_usize().ok_or_else(|| {
                                anyhow::anyhow!(
                                    "pool.admission.weights entries must be \
                                     non-negative integers"
                                )
                            })?;
                    }
                }
            }
            if let Some(ch) = p.get("chains") {
                // Strict throughout: a malformed chain route must be a
                // startup error, never a silently chainless gateway.
                for (ti, origin) in ["small", "medium", "large"].iter().enumerate()
                {
                    if let Some(v) = ch.get(origin) {
                        let arr = v.as_arr().ok_or_else(|| {
                            anyhow::anyhow!(
                                "pool.chains.{origin} must be an array of tier \
                                 names"
                            )
                        })?;
                        let mut route = Vec::new();
                        for e in arr {
                            let name = e.as_str().ok_or_else(|| {
                                anyhow::anyhow!(
                                    "pool.chains.{origin} entries must be tier \
                                     name strings"
                                )
                            })?;
                            let target =
                                chain_tier_index(name).ok_or_else(|| {
                                    anyhow::anyhow!(
                                        "pool.chains.{origin}: unknown tier \
                                         `{name}`"
                                    )
                                })?;
                            if target == ti {
                                return Err(anyhow::anyhow!(
                                    "pool.chains.{origin}: a route cannot \
                                     target its own origin tier"
                                ));
                            }
                            route.push(target);
                        }
                        self.pool.chains.routes[ti] = route;
                    }
                }
                self.pool.chains.max_retries =
                    ch.usize_or("max_retries", self.pool.chains.max_retries);
                self.pool.chains.backoff_base_s =
                    ch.f64_or("backoff_base_s", self.pool.chains.backoff_base_s);
                self.pool.chains.retry_budget_ratio = ch.f64_or(
                    "retry_budget_ratio",
                    self.pool.chains.retry_budget_ratio,
                );
                self.pool.chains.score_floor =
                    ch.f64_or("score_floor", self.pool.chains.score_floor);
                self.pool.chains.degrade =
                    ch.bool_or("degrade", self.pool.chains.degrade);
            }
            if let Some(t) = p.get("trace") {
                self.pool.trace.enabled =
                    t.bool_or("enabled", self.pool.trace.enabled);
                self.pool.trace.ring_size =
                    t.usize_or("ring_size", self.pool.trace.ring_size);
                self.pool.trace.sample_rate =
                    t.f64_or("sample_rate", self.pool.trace.sample_rate);
                if let Some(a) = t.get("access_log").and_then(Json::as_str) {
                    self.pool.trace.access_log = a.to_string();
                }
            }
            if let Some(r) = p.get("routing") {
                if let Some(b) = r.get("bandit") {
                    self.pool.routing.bandit.enabled =
                        b.bool_or("enabled", self.pool.routing.bandit.enabled);
                    self.pool.routing.bandit.epsilon =
                        b.f64_or("epsilon", self.pool.routing.bandit.epsilon);
                    self.pool.routing.bandit.window =
                        b.usize_or("window", self.pool.routing.bandit.window);
                    self.pool.routing.bandit.min_samples = b
                        .usize_or("min_samples", self.pool.routing.bandit.min_samples);
                }
            }
            self.pool.scale_interval_s =
                p.f64_or("scale_interval_s", self.pool.scale_interval_s);
            self.pool.health_deadline_s =
                p.f64_or("health_deadline_s", self.pool.health_deadline_s);
            if let Some(s) = p.get("substrate").and_then(Json::as_str) {
                self.pool.substrate = SubstrateKind::parse(s)
                    .ok_or_else(|| anyhow::anyhow!("bad pool substrate `{s}`"))?;
            }
            if let Some(b) = p.get("worker_bin").and_then(Json::as_str) {
                self.pool.worker_bin = Some(b.to_string());
            }
            if let Some(d) = p.get("worker_log_dir").and_then(Json::as_str) {
                self.pool.worker_log_dir = Some(d.to_string());
            }
            if let Some(n) = p.get("nodes") {
                // Strict throughout: a malformed node plane must be a
                // startup error, never a silently smaller (or local)
                // fleet.
                if let Some(v) = n.get("listen_addr") {
                    self.pool.nodes.listen_addr = Some(
                        v.as_str()
                            .ok_or_else(|| {
                                anyhow::anyhow!(
                                    "pool.nodes.listen_addr must be a string"
                                )
                            })?
                            .to_string(),
                    );
                }
                if let Some(v) = n.get("agents") {
                    let arr = v.as_arr().ok_or_else(|| {
                        anyhow::anyhow!("pool.nodes.agents must be an array")
                    })?;
                    self.pool.nodes.agents = arr
                        .iter()
                        .map(|e| {
                            e.as_str().map(|s| s.to_string()).ok_or_else(|| {
                                anyhow::anyhow!(
                                    "pool.nodes.agents entries must be strings"
                                )
                            })
                        })
                        .collect::<Result<Vec<String>>>()?;
                }
                if let Some(v) = n.get("placement") {
                    let pl = v.as_str().ok_or_else(|| {
                        anyhow::anyhow!("pool.nodes.placement must be a string")
                    })?;
                    self.pool.nodes.placement = Placement::parse(pl)
                        .ok_or_else(|| anyhow::anyhow!("bad placement `{pl}`"))?;
                }
            }
        }
        if let Some(c) = j.get("cluster") {
            self.cluster.gpus_per_node =
                c.usize_or("gpus_per_node", self.cluster.gpus_per_node);
            self.cluster.nodes = c.usize_or("nodes", self.cluster.nodes);
            self.cluster.image_pull_cold_s =
                c.f64_or("image_pull_cold_s", self.cluster.image_pull_cold_s);
            self.cluster.image_pull_cached_s =
                c.f64_or("image_pull_cached_s", self.cluster.image_pull_cached_s);
            self.cluster.pvc_bandwidth_gbps =
                c.f64_or("pvc_bandwidth_gbps", self.cluster.pvc_bandwidth_gbps);
            self.cluster.engine_init_s =
                c.f64_or("engine_init_s", self.cluster.engine_init_s);
            self.cluster.failure_rate_per_hour =
                c.f64_or("failure_rate_per_hour", self.cluster.failure_rate_per_hour);
        }
        if let Some(p) = j.get("profile").and_then(Json::as_str) {
            self.profile = Profile::by_name(p)
                .ok_or_else(|| anyhow::anyhow!("unknown profile `{p}`"))?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profiles_match_paper() {
        assert_eq!(Profile::QUALITY.alpha, 1.0);
        assert_eq!(Profile::COST.mu, 0.8);
        assert_eq!(Profile::SPEED.lambda, 0.8);
        assert_eq!(Profile::BALANCED.alpha, 0.5);
        assert_eq!(Profile::by_name("quality"), Some(Profile::QUALITY));
        assert_eq!(Profile::by_name("nope"), None);
    }

    #[test]
    fn defaults_are_sane() {
        let c = Config::default();
        assert_eq!(c.orchestrator.telemetry_window_s, 300.0);
        assert!(c.orchestrator.cooldown_s > 0.0);
        assert_eq!(c.router.mode, RouterMode::Hybrid);
    }

    #[test]
    fn overlay_partial() {
        let mut c = Config::default();
        let j = Json::parse(
            r#"{"router":{"mode":"keyword"},
                "orchestrator":{"idle_timeout_s":60,"warm_pool":[2,1,1]},
                "profile":"cost"}"#,
        )
        .unwrap();
        c.overlay(&j).unwrap();
        assert_eq!(c.router.mode, RouterMode::Keyword);
        assert_eq!(c.orchestrator.idle_timeout_s, 60.0);
        assert_eq!(c.orchestrator.warm_pool, [2, 1, 1]);
        assert_eq!(c.profile, Profile::COST);
        // untouched fields keep defaults
        assert_eq!(c.gateway.port, 8080);
    }

    #[test]
    fn pool_defaults_are_sane() {
        let c = Config::default();
        assert_eq!(c.pool.replicas, [1, 1, 1]);
        assert!(c.pool.max_inflight >= c.pool.max_decode_batch);
        assert!(c.pool.flush_timeout_s > 0.0);
        // The KV pool must fit at least one full-budget sequence.
        assert!(c.pool.kv_blocks * c.pool.kv_block_tokens >= 256);
    }

    #[test]
    fn overlay_pool_section() {
        let mut c = Config::default();
        let j = Json::parse(
            r#"{"pool":{"replicas":[2,2,1],"max_inflight":16,
                "flush_timeout_s":0.004,"queue_capacity":64}}"#,
        )
        .unwrap();
        c.overlay(&j).unwrap();
        assert_eq!(c.pool.replicas, [2, 2, 1]);
        assert_eq!(c.pool.max_inflight, 16);
        assert_eq!(c.pool.queue_capacity, 64);
        assert!((c.pool.flush_timeout_s - 0.004).abs() < 1e-12);
        // untouched knobs keep defaults
        assert_eq!(c.pool.max_decode_batch, 8);
        assert_eq!(c.pool.max_prefill_batch, 4);
        assert_eq!(c.pool.kv_blocks, 128);
        assert!((c.pool.health_deadline_s - 3.0).abs() < 1e-12);
        assert!(c.pool.prefix_cache.enabled, "prefix cache defaults on");
    }

    #[test]
    fn overlay_prefix_cache_section() {
        let mut c = Config::default();
        assert!(c.pool.prefix_cache.enabled);
        assert_eq!(c.pool.prefix_cache.min_block_run, 1);
        assert!((c.pool.prefix_cache.evict_watermark - 0.9).abs() < 1e-12);
        let j = Json::parse(
            r#"{"pool":{"prefix_cache":{"enabled":false,"min_block_run":2,
                "evict_watermark":0.75}}}"#,
        )
        .unwrap();
        c.overlay(&j).unwrap();
        assert!(!c.pool.prefix_cache.enabled);
        assert_eq!(c.pool.prefix_cache.min_block_run, 2);
        assert!((c.pool.prefix_cache.evict_watermark - 0.75).abs() < 1e-12);
        // untouched pool knobs keep defaults
        assert_eq!(c.pool.kv_blocks, 128);
    }

    #[test]
    fn overlay_affinity_section() {
        let mut c = Config::default();
        assert!(!c.pool.affinity.enabled, "affinity routing defaults off");
        assert_eq!(c.pool.affinity.top_k, 8);
        assert_eq!(c.pool.affinity.min_match_blocks, 1);
        assert!(c.pool.affinity.transfer);
        let j = Json::parse(
            r#"{"pool":{"affinity":{"enabled":true,"top_k":4,
                "min_match_blocks":2,"transfer":false}}}"#,
        )
        .unwrap();
        c.overlay(&j).unwrap();
        assert!(c.pool.affinity.enabled);
        assert_eq!(c.pool.affinity.top_k, 4);
        assert_eq!(c.pool.affinity.min_match_blocks, 2);
        assert!(!c.pool.affinity.transfer);
        // untouched pool knobs keep defaults
        assert_eq!(c.pool.kv_blocks, 128);
        assert!(c.pool.prefix_cache.enabled);
    }

    #[test]
    fn overlay_speculative_section() {
        let mut c = Config::default();
        assert!(!c.pool.speculative.enabled, "speculative decode defaults off");
        assert_eq!(c.pool.speculative.draft_tier, 0);
        assert_eq!(c.pool.speculative.draft_tokens, 4);
        assert!((c.pool.speculative.min_accept_rate - 0.3).abs() < 1e-12);
        assert!((c.pool.speculative.sim_accept - 0.75).abs() < 1e-12);
        let j = Json::parse(
            r#"{"pool":{"speculative":{"enabled":true,"draft_tier":1,
                "draft_tokens":6,"min_accept_rate":0.5,"sim_accept":0.8}}}"#,
        )
        .unwrap();
        c.overlay(&j).unwrap();
        assert!(c.pool.speculative.enabled);
        assert_eq!(c.pool.speculative.draft_tier, 1);
        assert_eq!(c.pool.speculative.draft_tokens, 6);
        assert!((c.pool.speculative.min_accept_rate - 0.5).abs() < 1e-12);
        assert!((c.pool.speculative.sim_accept - 0.8).abs() < 1e-12);
        // untouched pool knobs keep defaults
        assert_eq!(c.pool.kv_blocks, 128);
        assert!(!c.pool.affinity.enabled);
        // Pairing rule: only tiers strictly above the draft tier verify.
        assert!(c.pool.speculative.pairs_with(2));
        assert!(!c.pool.speculative.pairs_with(1), "draft tier never verifies");
        assert!(!c.pool.speculative.pairs_with(0));
        assert!(!SpeculativeConfig::disabled().pairs_with(2), "off ⇒ no pairs");
    }

    #[test]
    fn overlay_routing_section() {
        let mut c = Config::default();
        assert!(!c.pool.routing.bandit.enabled, "bandit defaults off");
        assert!((c.pool.routing.bandit.epsilon - 0.05).abs() < 1e-12);
        assert_eq!(c.pool.routing.bandit.window, 256);
        assert_eq!(c.pool.routing.bandit.min_samples, 10);
        let j = Json::parse(
            r#"{"pool":{"routing":{"bandit":{"enabled":true,"epsilon":0.2,
                "window":64,"min_samples":5}}}}"#,
        )
        .unwrap();
        c.overlay(&j).unwrap();
        assert!(c.pool.routing.bandit.enabled);
        assert!((c.pool.routing.bandit.epsilon - 0.2).abs() < 1e-12);
        assert_eq!(c.pool.routing.bandit.window, 64);
        assert_eq!(c.pool.routing.bandit.min_samples, 5);
        // untouched pool knobs keep defaults
        assert_eq!(c.pool.kv_blocks, 128);
        assert!(!c.pool.affinity.enabled);
    }

    #[test]
    fn overlay_substrate_section() {
        let mut c = Config::default();
        assert_eq!(c.pool.substrate, SubstrateKind::Thread, "thread by default");
        assert!(c.pool.worker_bin.is_none());
        let j = Json::parse(
            r#"{"pool":{"substrate":"process","worker_bin":"/usr/bin/ps",
                "worker_log_dir":"/tmp/logs"}}"#,
        )
        .unwrap();
        c.overlay(&j).unwrap();
        assert_eq!(c.pool.substrate, SubstrateKind::Process);
        assert_eq!(c.pool.worker_bin.as_deref(), Some("/usr/bin/ps"));
        assert_eq!(c.pool.worker_log_dir.as_deref(), Some("/tmp/logs"));
        // untouched pool knobs keep defaults
        assert_eq!(c.pool.kv_blocks, 128);

        let bad = Json::parse(r#"{"pool":{"substrate":"serverless"}}"#).unwrap();
        assert!(c.overlay(&bad).is_err());
        assert_eq!(SubstrateKind::parse("thread"), Some(SubstrateKind::Thread));
        assert_eq!(SubstrateKind::Process.name(), "process");
    }

    #[test]
    fn overlay_nodes_section() {
        let mut c = Config::default();
        assert!(!c.pool.nodes.configured(), "node plane off by default");
        assert_eq!(c.pool.nodes.placement, Placement::Spread);
        let j = Json::parse(
            r#"{"pool":{"nodes":{"listen_addr":"0.0.0.0:7070",
                "agents":["10.0.0.5:7071","10.0.0.6:7071"],
                "placement":"pack"}}}"#,
        )
        .unwrap();
        c.overlay(&j).unwrap();
        assert!(c.pool.nodes.configured());
        assert_eq!(c.pool.nodes.listen_addr.as_deref(), Some("0.0.0.0:7070"));
        assert_eq!(c.pool.nodes.agents.len(), 2);
        assert_eq!(c.pool.nodes.placement, Placement::Pack);
        // untouched pool knobs keep defaults
        assert_eq!(c.pool.substrate, SubstrateKind::Thread);

        let bad = Json::parse(r#"{"pool":{"nodes":{"placement":"anywhere"}}}"#)
            .unwrap();
        assert!(c.overlay(&bad).is_err());
        // Malformed agent lists error loudly instead of shrinking the
        // fleet to (or past) single-host.
        let bad = Json::parse(r#"{"pool":{"nodes":{"agents":"10.0.0.5:7071"}}}"#)
            .unwrap();
        assert!(c.overlay(&bad).is_err(), "non-array agents must error");
        let bad =
            Json::parse(r#"{"pool":{"nodes":{"agents":["10.0.0.5:7071",7071]}}}"#)
                .unwrap();
        assert!(c.overlay(&bad).is_err(), "non-string agent entry must error");
        let bad = Json::parse(r#"{"pool":{"nodes":{"listen_addr":7070}}}"#).unwrap();
        assert!(c.overlay(&bad).is_err(), "non-string listen_addr must error");
        assert_eq!(Placement::parse("spread"), Some(Placement::Spread));
        assert_eq!(Placement::Pack.name(), "pack");
    }

    #[test]
    fn overlay_admission_section() {
        let mut c = Config::default();
        assert!(!c.pool.admission.enabled, "admission control defaults off");
        assert!((c.pool.admission.watermark - 0.75).abs() < 1e-12);
        assert_eq!(c.pool.admission.weights, [4, 2, 1]);
        let j = Json::parse(
            r#"{"pool":{"admission":{"enabled":true,"watermark":0.5,
                "weights":[8,3,1]}}}"#,
        )
        .unwrap();
        c.overlay(&j).unwrap();
        assert!(c.pool.admission.enabled);
        assert!((c.pool.admission.watermark - 0.5).abs() < 1e-12);
        assert_eq!(c.pool.admission.weights, [8, 3, 1]);
        // untouched pool knobs keep defaults
        assert_eq!(c.pool.kv_blocks, 128);

        let bad =
            Json::parse(r#"{"pool":{"admission":{"weights":"high"}}}"#).unwrap();
        assert!(c.overlay(&bad).is_err(), "non-array weights must error");
        let bad =
            Json::parse(r#"{"pool":{"admission":{"weights":[1,"x",3]}}}"#)
                .unwrap();
        assert!(c.overlay(&bad).is_err(), "non-integer weight must error");
        assert_eq!(Priority::parse("interactive"), Some(Priority::Interactive));
        assert_eq!(Priority::parse("rush"), None);
        assert_eq!(Priority::Batch.name(), "batch");
        assert_eq!(Priority::default(), Priority::Standard);
        assert_eq!(Priority::from_index(0), Priority::Interactive);
        assert_eq!(Priority::from_index(9), Priority::Batch);
    }

    #[test]
    fn overlay_chains_section() {
        let mut c = Config::default();
        assert!(!c.pool.chains.any(), "no chains by default");
        assert_eq!(c.pool.chains.max_retries, 2);
        let j = Json::parse(
            r#"{"pool":{"chains":{"small":["medium","large"],
                "medium":["large"],"max_retries":3,"backoff_base_s":0.01,
                "retry_budget_ratio":0.25,"score_floor":0.4,
                "degrade":false}}}"#,
        )
        .unwrap();
        c.overlay(&j).unwrap();
        assert!(c.pool.chains.any());
        assert_eq!(c.pool.chains.routes[0], vec![1, 2]);
        assert_eq!(c.pool.chains.routes[1], vec![2]);
        assert!(c.pool.chains.routes[2].is_empty());
        assert_eq!(c.pool.chains.max_retries, 3);
        assert!((c.pool.chains.backoff_base_s - 0.01).abs() < 1e-12);
        assert!((c.pool.chains.retry_budget_ratio - 0.25).abs() < 1e-12);
        assert!((c.pool.chains.score_floor - 0.4).abs() < 1e-12);
        assert!(!c.pool.chains.degrade);
        // untouched pool knobs keep defaults
        assert_eq!(c.pool.kv_blocks, 128);

        let bad =
            Json::parse(r#"{"pool":{"chains":{"small":"medium"}}}"#).unwrap();
        assert!(c.overlay(&bad).is_err(), "non-array route must error");
        let bad =
            Json::parse(r#"{"pool":{"chains":{"small":["huge"]}}}"#).unwrap();
        assert!(c.overlay(&bad).is_err(), "unknown tier name must error");
        let bad =
            Json::parse(r#"{"pool":{"chains":{"small":["small"]}}}"#).unwrap();
        assert!(c.overlay(&bad).is_err(), "self-targeting route must error");
        let bad = Json::parse(r#"{"pool":{"chains":{"medium":[2]}}}"#).unwrap();
        assert!(c.overlay(&bad).is_err(), "non-string route entry must error");
    }

    #[test]
    fn overlay_trace_section() {
        let mut c = Config::default();
        assert!(!c.pool.trace.enabled, "tracing defaults off");
        assert_eq!(c.pool.trace.ring_size, 256);
        assert!((c.pool.trace.sample_rate - 1.0).abs() < 1e-12);
        assert!(c.pool.trace.access_log.is_empty());
        let j = Json::parse(
            r#"{"pool":{"trace":{"enabled":true,"ring_size":64,
                "sample_rate":0.5,"access_log":"stderr"}}}"#,
        )
        .unwrap();
        c.overlay(&j).unwrap();
        assert!(c.pool.trace.enabled);
        assert_eq!(c.pool.trace.ring_size, 64);
        assert!((c.pool.trace.sample_rate - 0.5).abs() < 1e-12);
        assert_eq!(c.pool.trace.access_log, "stderr");
        // untouched pool knobs keep defaults
        assert_eq!(c.pool.kv_blocks, 128);
    }

    #[test]
    fn overlay_rejects_bad_mode() {
        let mut c = Config::default();
        let j = Json::parse(r#"{"router":{"mode":"quantum"}}"#).unwrap();
        assert!(c.overlay(&j).is_err());
    }

    #[test]
    fn router_mode_parse() {
        assert_eq!(RouterMode::parse("distilbert"), Some(RouterMode::Semantic));
        assert_eq!(RouterMode::parse("hybrid").unwrap().name(), "hybrid");
    }
}
