//! Baseline policies the paper compares against.
//!
//! * Selection strategies (Table 3): random assignment and latency-only,
//!   vs Pick-and-Spin's multi-objective matrix policy.
//! * Deployment modes (Tables 1/4): static always-on deployment vs
//!   dynamic orchestration (scale-to-zero + warm pools + auto recovery).

use crate::registry::{Registry, Service, ServiceId};
use crate::router::Classification;
use crate::scoring::Weights;
use crate::util::rng::SplitMix64;

/// How a service is chosen for a classified prompt.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SelectionPolicy {
    /// Eq. 2 / Alg. 2 (the paper's contribution).
    MultiObjective,
    /// Uniform-random over routable services (Table 3 baseline).
    Random,
    /// Minimize expected latency only (Table 3 baseline).
    LatencyOnly,
    /// Round-robin over models on the default backend, ignoring the
    /// classification (Table 1's unrouted static baseline).
    RoundRobin,
    /// Tier-directed routing (paper §Routing: "routes queries to model
    /// tiers L1–L3 based on complexity"): the predicted class fixes the
    /// model tier, Eq. 2 picks the best cell within that tier. This is
    /// the configuration behind Table 2 and Figs. 4–7.
    TierDirected,
}

impl SelectionPolicy {
    pub fn name(self) -> &'static str {
        match self {
            SelectionPolicy::MultiObjective => "multi-objective",
            SelectionPolicy::Random => "random",
            SelectionPolicy::LatencyOnly => "latency-only",
            SelectionPolicy::RoundRobin => "round-robin",
            SelectionPolicy::TierDirected => "tier-directed",
        }
    }
}

/// Stateful selector wrapping all policies behind one call.
pub struct Selector {
    pub policy: SelectionPolicy,
    pub weights: Weights,
    rng: SplitMix64,
    rr_next: usize,
}

impl Selector {
    pub fn new(policy: SelectionPolicy, weights: Weights, seed: u64) -> Self {
        Self { policy, weights, rng: SplitMix64::new(seed), rr_next: 0 }
    }

    /// Choose a service for a classified prompt.
    pub fn select(
        &mut self,
        registry: &Registry,
        class: &Classification,
        in_tokens: f64,
        out_tokens: f64,
        cold_start_of: impl Fn(&Service) -> f64,
    ) -> Option<ServiceId> {
        match self.policy {
            SelectionPolicy::MultiObjective => crate::orchestrator::select(
                registry,
                self.weights,
                class,
                in_tokens,
                out_tokens,
                cold_start_of,
            )
            .map(|s| s.service),
            SelectionPolicy::Random => {
                let cands: Vec<ServiceId> =
                    registry.routable().map(|s| s.id).collect();
                if cands.is_empty() {
                    None
                } else {
                    Some(cands[self.rng.below(cands.len() as u64) as usize])
                }
            }
            SelectionPolicy::LatencyOnly => registry
                .routable()
                .map(|s| {
                    let t = s.expected_latency_s(
                        in_tokens,
                        out_tokens,
                        cold_start_of(s),
                    );
                    (s.id, t)
                })
                .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
                .map(|(id, _)| id),
            SelectionPolicy::RoundRobin => {
                // Model rows on the default (vLLM) backend column.
                let cands: Vec<ServiceId> = registry
                    .routable()
                    .filter(|s| s.backend == crate::models::BackendKind::Vllm)
                    .map(|s| s.id)
                    .collect();
                if cands.is_empty() {
                    return None;
                }
                let id = cands[self.rr_next % cands.len()];
                self.rr_next += 1;
                Some(id)
            }
            SelectionPolicy::TierDirected => {
                let tier = crate::models::Tier::for_complexity(class.complexity);
                // Best Eq. 2 score among the predicted tier's cells; the
                // class fixes the row group, the score picks the backend.
                let mut best: Option<(ServiceId, f64)> = None;
                for s in registry.routable().filter(|s| s.spec.tier == tier) {
                    let t = s.expected_latency_s(in_tokens, out_tokens,
                                                 cold_start_of(s));
                    let c = s.expected_cost_usd(in_tokens, out_tokens);
                    // Within one tier relevance is constant; score on
                    // latency+cost with the profile's relative weights.
                    let f = -(self.weights.w_t * t
                        + self.weights.w_c * c * 1e3
                        + (1.0 - self.weights.w_t - self.weights.w_c) * t);
                    if best.map(|(_, bf)| f > bf).unwrap_or(true) {
                        best = Some((s.id, f));
                    }
                }
                best.map(|(id, _)| id)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Profile, RouterMode};
    use crate::models::zoo;
    use crate::registry::Registry;

    fn setup() -> Registry {
        let mut r = Registry::new(&zoo(), 300.0);
        for s in &mut r.services {
            s.ready_replicas = 1;
        }
        r
    }

    fn class() -> Classification {
        Classification {
            complexity: 1,
            confidence: 0.9,
            mode: RouterMode::Hybrid,
            overhead_s: 0.0,
        }
    }

    #[test]
    fn round_robin_cycles_models() {
        let r = setup();
        let mut sel = Selector::new(
            SelectionPolicy::RoundRobin,
            Weights::from_profile(&Profile::BASELINE),
            0,
        );
        let picks: Vec<ServiceId> = (0..8)
            .map(|_| sel.select(&r, &class(), 50.0, 50.0, |_| 0.0).unwrap())
            .collect();
        assert_eq!(picks[0], picks[4]);
        assert_eq!(picks[1], picks[5]);
        let distinct: std::collections::BTreeSet<_> = picks.iter().collect();
        assert_eq!(distinct.len(), 4);
    }

    #[test]
    fn latency_only_picks_fastest() {
        let r = setup();
        let mut sel = Selector::new(
            SelectionPolicy::LatencyOnly,
            Weights::from_profile(&Profile::BASELINE),
            0,
        );
        let id = sel.select(&r, &class(), 100.0, 100.0, |_| 0.0).unwrap();
        let svc = r.get(id);
        // Fastest cell: the small model on the latency backend.
        assert_eq!(svc.spec.name, "gemma3-27b");
        assert_eq!(svc.backend, crate::models::BackendKind::TrtLlm);
    }

    #[test]
    fn random_covers_the_matrix() {
        let r = setup();
        let mut sel = Selector::new(
            SelectionPolicy::Random,
            Weights::from_profile(&Profile::BASELINE),
            7,
        );
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..300 {
            seen.insert(sel.select(&r, &class(), 50.0, 50.0, |_| 0.0).unwrap());
        }
        assert_eq!(seen.len(), 12);
    }

    #[test]
    fn multi_objective_delegates_to_alg2() {
        let r = setup();
        let mut sel = Selector::new(
            SelectionPolicy::MultiObjective,
            Weights::from_profile(&Profile::QUALITY),
            0,
        );
        let hard = Classification { complexity: 2, ..class() };
        let id = sel.select(&r, &hard, 100.0, 200.0, |_| 0.0).unwrap();
        assert!(r.get(id).spec.capability[2] > 0.85);
    }
}
