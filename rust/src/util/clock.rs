//! Clock abstraction: one trait, two implementations.
//!
//! The whole control plane is written against [`Clock`] so the same
//! router/orchestrator/backend code runs in **live** mode (wall time,
//! real PJRT inference) and **sim** mode (virtual time driven by the
//! discrete-event engine, where the 163k-run paper tables finish in
//! seconds).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Monotonic time source measured in nanoseconds from an arbitrary epoch.
pub trait Clock: Send + Sync {
    fn now_ns(&self) -> u64;

    fn now_secs(&self) -> f64 {
        self.now_ns() as f64 / 1e9
    }
}

/// Wall-clock implementation.
pub struct RealClock {
    epoch: Instant,
}

impl RealClock {
    pub fn new() -> Self {
        Self { epoch: Instant::now() }
    }
}

impl Default for RealClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for RealClock {
    fn now_ns(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }
}

/// Virtual clock — advanced explicitly by the discrete-event engine.
#[derive(Default)]
pub struct VirtualClock {
    ns: AtomicU64,
}

impl VirtualClock {
    pub fn new() -> Arc<Self> {
        Arc::new(Self { ns: AtomicU64::new(0) })
    }

    /// Advance to an absolute timestamp. Monotonic by construction:
    /// `fetch_max` ignores timestamps in the past.
    pub fn advance_to(&self, t_ns: u64) {
        self.ns.fetch_max(t_ns, Ordering::SeqCst);
    }

    /// Advance by a delta, returning the new now.
    pub fn advance_by(&self, delta_ns: u64) -> u64 {
        self.ns.fetch_add(delta_ns, Ordering::SeqCst) + delta_ns
    }
}

impl Clock for VirtualClock {
    fn now_ns(&self) -> u64 {
        self.ns.load(Ordering::SeqCst)
    }
}

/// Seconds → nanoseconds helper (f64 seconds are the config-facing unit).
pub fn secs_to_ns(s: f64) -> u64 {
    (s * 1e9) as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn real_clock_is_monotonic() {
        let c = RealClock::new();
        let a = c.now_ns();
        let b = c.now_ns();
        assert!(b >= a);
    }

    #[test]
    fn virtual_clock_advances() {
        let c = VirtualClock::new();
        assert_eq!(c.now_ns(), 0);
        c.advance_to(5_000);
        assert_eq!(c.now_ns(), 5_000);
        c.advance_by(1_000);
        assert_eq!(c.now_ns(), 6_000);
        assert!((c.now_secs() - 6e-6).abs() < 1e-12);
    }

    #[test]
    fn virtual_clock_never_regresses() {
        let c = VirtualClock::new();
        c.advance_to(100);
        c.advance_to(50); // fetch_max keeps 100
        assert_eq!(c.now_ns(), 100);
    }

    #[test]
    fn conversion() {
        assert_eq!(secs_to_ns(1.5), 1_500_000_000);
    }
}
