//! Minimal JSON parser/serializer (serde is unavailable offline).
//!
//! Supports the full JSON grammar with the usual Rust conveniences:
//! typed accessors, an index-by-key `get` API, and a compact builder for
//! report output. Numbers are kept as `f64` (adequate for every value in
//! this project's manifests and reports; integers are exact to 2^53).

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{anyhow, bail, Context, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Object keys are sorted (BTreeMap) so serialization is deterministic.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document from text.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    /// Parse the contents of a file.
    pub fn from_file(path: &str) -> Result<Json> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {path}"))?;
        Self::parse(&text).with_context(|| format!("parsing {path}"))
    }

    // ---- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that errors with the key name — for required fields.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key `{key}`"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|f| if f >= 0.0 { Some(f as usize) } else { None })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Required-field convenience: `v.rstr("name")?`
    pub fn rstr(&self, key: &str) -> Result<&str> {
        self.req(key)?.as_str().ok_or_else(|| anyhow!("`{key}` is not a string"))
    }

    pub fn rf64(&self, key: &str) -> Result<f64> {
        self.req(key)?.as_f64().ok_or_else(|| anyhow!("`{key}` is not a number"))
    }

    pub fn rusize(&self, key: &str) -> Result<usize> {
        self.req(key)?
            .as_usize()
            .ok_or_else(|| anyhow!("`{key}` is not a non-negative integer"))
    }

    pub fn rarr(&self, key: &str) -> Result<&[Json]> {
        self.req(key)?.as_arr().ok_or_else(|| anyhow!("`{key}` is not an array"))
    }

    /// Optional field with default.
    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Json::as_f64).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(Json::as_usize).unwrap_or(default)
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(Json::as_str).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(Json::as_bool).unwrap_or(default)
    }

    // ---- builders --------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Serialize compactly.
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Serialize with indentation.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(1), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.dump())
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            '\u{8}' => out.push_str("\\b"),
            '\u{c}' => out.push_str("\\f"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected `{}` at byte {}, found `{}`",
                  c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected `,` or `}}`, found `{}`", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                c => bail!("expected `,` or `]`, found `{}`", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let code = self.hex4()?;
                            let ch = if (0xD800..0xDC00).contains(&code) {
                                // High surrogate: a low surrogate escape
                                // must follow, and both halves are
                                // range-checked *before* any arithmetic —
                                // `\ud800\ud800` must be a parse error,
                                // not an integer under/overflow.
                                if self.b.get(self.i) == Some(&b'\\')
                                    && self.b.get(self.i + 1) == Some(&b'u')
                                {
                                    self.i += 2;
                                    let low = self.hex4()?;
                                    if !(0xDC00..0xE000).contains(&low) {
                                        bail!(
                                            "high surrogate \\u{code:04x} \
                                             followed by non-low \\u{low:04x}"
                                        );
                                    }
                                    let c = 0x10000
                                        + ((code - 0xD800) << 10)
                                        + (low - 0xDC00);
                                    char::from_u32(c)
                                        .ok_or_else(|| anyhow!("bad surrogate pair"))?
                                } else {
                                    bail!("lone high surrogate \\u{code:04x}");
                                }
                            } else if (0xDC00..0xE000).contains(&code) {
                                bail!("lone low surrogate \\u{code:04x}");
                            } else {
                                char::from_u32(code)
                                    .ok_or_else(|| anyhow!("bad codepoint"))?
                            };
                            s.push(ch);
                        }
                        _ => bail!("bad escape `\\{}`", e as char),
                    }
                }
                c if c < 0x20 => {
                    // RFC 8259: control characters must be escaped. The
                    // serializer always escapes them, so accepting raw
                    // ones would only mask producer bugs.
                    bail!("raw control character 0x{c:02x} in string at byte {}",
                          self.i - 1);
                }
                _ => {
                    // re-decode UTF-8 from the raw bytes
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    let end = start + len;
                    if end > self.b.len() {
                        bail!("truncated utf-8");
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..end])?);
                    self.i = end;
                }
            }
        }
    }

    /// Exactly four ASCII hex digits of a `\u` escape. Strict by hand:
    /// `from_str_radix` would also accept a leading `+`, quietly turning
    /// `\u+0ab` into a codepoint.
    fn hex4(&mut self) -> Result<u32> {
        let hex = self
            .b
            .get(self.i..self.i + 4)
            .ok_or_else(|| anyhow!("truncated \\u escape at byte {}", self.i))?;
        let mut code: u32 = 0;
        for &b in hex {
            let d = match b {
                b'0'..=b'9' => b - b'0',
                b'a'..=b'f' => b - b'a' + 10,
                b'A'..=b'F' => b - b'A' + 10,
                _ => bail!("invalid hex digit `{}` in \\u escape", b as char),
            };
            code = (code << 4) | d as u32;
        }
        self.i += 4;
        Ok(code)
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i],
                        b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        let n: f64 = text
            .parse()
            .map_err(|_| anyhow!("invalid number `{text}` at byte {start}"))?;
        Ok(Json::Num(n))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" 42 ").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a[1].as_f64().unwrap(), 2.0);
        assert_eq!(a[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"nested":{"arr":[1,2.5,"s",true,null]},"z":"€ uni\n"}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.dump()).unwrap();
        assert_eq!(v, v2);
        let v3 = Json::parse(&v.pretty()).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn escapes() {
        let v = Json::parse(r#""a\tbAé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\tbAé");
    }

    #[test]
    fn surrogate_pair() {
        let v = Json::parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "😀");
    }

    #[test]
    fn errors() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("01x").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("{} extra").is_err());
    }

    #[test]
    fn typed_accessors() {
        let v = Json::parse(r#"{"n":3,"s":"x","b":true,"a":[]}"#).unwrap();
        assert_eq!(v.rusize("n").unwrap(), 3);
        assert_eq!(v.rstr("s").unwrap(), "x");
        assert!(v.bool_or("b", false));
        assert_eq!(v.usize_or("missing", 7), 7);
        assert!(v.rstr("missing").is_err());
        assert!(v.rf64("s").is_err());
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"日本語 ünïcödé\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "日本語 ünïcödé");
    }

    #[test]
    fn control_characters_roundtrip() {
        // Every C0 control character must survive dump → parse intact
        // (RPC frames carry user prompt text; a lossy escape corrupts
        // jobs on the wire).
        let s: String = (0u32..0x20).map(|c| char::from_u32(c).unwrap()).collect();
        let v = Json::Str(s.clone());
        let dumped = v.dump();
        assert!(
            dumped.bytes().all(|b| b >= 0x20),
            "control chars must be escaped in output: {dumped:?}"
        );
        assert_eq!(Json::parse(&dumped).unwrap().as_str().unwrap(), s);
        // Short escapes for backspace/formfeed, like every other writer.
        assert!(dumped.contains("\\b") && dumped.contains("\\f"), "{dumped}");
    }

    #[test]
    fn non_bmp_escapes_roundtrip() {
        // Escaped surrogate-pair form and raw UTF-8 form both decode to
        // the same astral codepoints, and dumping re-parses losslessly.
        let v = Json::parse(r#""😀 𤭢""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "😀 𤭢");
        let v2 = Json::parse(&v.dump()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn malformed_surrogates_error_instead_of_panicking() {
        // Two high surrogates: underflow in the pair arithmetic used to
        // abort debug builds; it must be a parse error.
        assert!(Json::parse(r#""\ud800\ud800""#).is_err());
        // High surrogate followed by a non-surrogate escape.
        assert!(Json::parse(r#""\ud800A""#).is_err());
        // Lone halves, either order.
        assert!(Json::parse(r#""\ud800""#).is_err());
        assert!(Json::parse(r#""\udc00""#).is_err());
        // Truncated escape at end of input.
        assert!(Json::parse(r#""\ud83d\ude0"#).is_err());
    }

    #[test]
    fn hex_escapes_are_strict() {
        // from_str_radix would accept a leading `+`; the grammar doesn't.
        assert!(Json::parse(r#""\u+0ab""#).is_err());
        assert!(Json::parse(r#""\u00g1""#).is_err());
        // Case-insensitive hex is fine.
        assert_eq!(
            Json::parse("\"\\u00e9\"").unwrap().as_str().unwrap(),
            "é"
        );
        assert_eq!(
            Json::parse("\"\\u00E9\"").unwrap().as_str().unwrap(),
            "é"
        );
    }

    #[test]
    fn raw_control_characters_are_rejected() {
        // RFC 8259: unescaped control characters are invalid in strings.
        assert!(Json::parse("\"a\u{1}b\"").is_err());
        assert!(Json::parse("\"a\nb\"").is_err());
        // The escaped forms parse fine.
        assert_eq!(
            Json::parse("\"a\\u0001\\nb\"").unwrap().as_str().unwrap(),
            "a\u{1}\nb"
        );
    }
}
