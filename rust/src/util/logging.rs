//! Leveled logger (the `log`/`env_logger` facade is unavailable offline).
//!
//! Global level set once at startup from `--log-level` or `PS_LOG`;
//! the macros are zero-cost below the active level apart from one atomic
//! load.

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn parse(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }

    pub fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn level() -> u8 {
    LEVEL.load(Ordering::Relaxed)
}

pub fn enabled(level: Level) -> bool {
    level as u8 <= LEVEL.load(Ordering::Relaxed)
}

/// Init from the `PS_LOG` env var (if set).
pub fn init_from_env() {
    if let Ok(v) = std::env::var("PS_LOG") {
        if let Some(l) = Level::parse(&v) {
            set_level(l);
        }
    }
}

#[doc(hidden)]
pub fn emit(level: Level, module: &str, args: std::fmt::Arguments<'_>) {
    eprintln!("[{} {}] {}", level.tag(), module, args);
}

#[macro_export]
macro_rules! log_at {
    ($level:expr, $($arg:tt)*) => {
        if $crate::util::logging::enabled($level) {
            $crate::util::logging::emit($level, module_path!(),
                                        format_args!($($arg)*));
        }
    };
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => { $crate::log_at!($crate::util::logging::Level::Error, $($arg)*) };
}

#[macro_export]
macro_rules! warn_ {
    ($($arg:tt)*) => { $crate::log_at!($crate::util::logging::Level::Warn, $($arg)*) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::log_at!($crate::util::logging::Level::Info, $($arg)*) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::log_at!($crate::util::logging::Level::Debug, $($arg)*) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)*) => { $crate::log_at!($crate::util::logging::Level::Trace, $($arg)*) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_levels() {
        assert_eq!(Level::parse("info"), Some(Level::Info));
        assert_eq!(Level::parse("WARN"), Some(Level::Warn));
        assert_eq!(Level::parse("nope"), None);
    }

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info); // restore default for other tests
    }
}
