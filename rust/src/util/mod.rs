//! Dependency substrates built from scratch for the offline environment:
//! JSON, PRNG/distributions, statistics, clocks, threadpool/channels,
//! logging, and CLI parsing.

pub mod args;
pub mod clock;
pub mod json;
pub mod logging;
pub mod rng;
pub mod stats;
pub mod threadpool;

/// Format a markdown-style table for report output (used by the bench
/// harnesses to print the paper's tables).
pub fn format_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let mut out = String::new();
    let fmt_row = |cells: &[String], widths: &[usize]| -> String {
        let mut s = String::from("|");
        for (i, c) in cells.iter().enumerate() {
            s.push_str(&format!(" {:<w$} |", c, w = widths[i]));
        }
        s.push('\n');
        s
    };
    out.push_str(&fmt_row(
        &headers.iter().map(|h| h.to_string()).collect::<Vec<_>>(),
        &widths,
    ));
    out.push('|');
    for w in &widths {
        out.push_str(&format!("{:-<w$}|", "", w = w + 2));
    }
    out.push('\n');
    for row in rows {
        out.push_str(&fmt_row(row, &widths));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_aligns() {
        let t = format_table(
            &["name", "value"],
            &[
                vec!["a".into(), "1".into()],
                vec!["longer".into(), "2.5".into()],
            ],
        );
        assert!(t.contains("| name   | value |"));
        assert!(t.contains("| longer | 2.5   |"));
        assert_eq!(t.lines().count(), 4);
    }
}
