//! Deterministic PRNG + distributions (the `rand` crate is unavailable).
//!
//! [`SplitMix64`] is bit-compatible with `python/compile/corpus.py` so the
//! Rust workload generator and the Python training corpus draw from the
//! same streams when seeded identically (checked by a shared test vector).

/// SplitMix64 — tiny, fast, and statistically solid for simulation use.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform integer in `[0, n)`. (Modulo bias is negligible for the
    /// n << 2^64 draws this project makes; matches the Python mirror.)
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        self.next_u64() % n
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponential variate with the given rate (λ) — inter-arrival times
    /// of the Poisson arrival process the workload generator uses.
    pub fn exp(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0);
        // Avoid ln(0)
        let u = 1.0 - self.f64();
        -u.ln() / rate
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        let z = (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        mean + std * z
    }

    /// Log-normal (latency jitter model).
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal(mu, sigma).exp()
    }

    /// Zipf-like rank selection over `n` items with exponent `s` — used for
    /// skewed benchmark popularity in workload mixes.
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        debug_assert!(n > 0);
        // Inverse-CDF over precomputable harmonic weights would allocate;
        // for the small n here, rejection-free linear scan is fine.
        let h: f64 = (1..=n).map(|k| 1.0 / (k as f64).powf(s)).sum();
        let mut u = self.f64() * h;
        for k in 1..=n {
            u -= 1.0 / (k as f64).powf(s);
            if u <= 0.0 {
                return k - 1;
            }
        }
        n - 1
    }

    /// Choose an element from a slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len() as u64) as usize]
    }

    /// In-place Fisher–Yates shuffle (matches the Python mirror's order).
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            items.swap(i, j);
        }
    }

    /// Derive an independent stream (e.g. per benchmark, per worker).
    pub fn fork(&mut self, tag: u64) -> SplitMix64 {
        SplitMix64::new(self.next_u64() ^ tag)
    }
}

/// FNV-1a 64 offset basis — the one copy of the constant; the tokenizer
/// word ids and the KV prefix cache's chained block hashes both build on
/// it (desynchronizing them would break sim/live hash compatibility).
pub const FNV64_OFFSET: u64 = 0xCBF29CE484222325;

/// FNV-1a 64 prime.
pub const FNV64_PRIME: u64 = 0x100000001B3;

/// One FNV-1a step: fold a byte into a running hash. Lets callers hash
/// incrementally (lowercasing, chaining) without materializing buffers.
#[inline]
pub fn fnv1a64_step(h: u64, b: u8) -> u64 {
    (h ^ b as u64).wrapping_mul(FNV64_PRIME)
}

/// FNV-1a 64 — mirrors `python/compile/tokenizer.py`.
pub fn fnv1a64(data: &[u8]) -> u64 {
    let mut h: u64 = FNV64_OFFSET;
    for &b in data {
        h = fnv1a64_step(h, b);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vectors() {
        // Must match python/tests/test_corpus.py::test_splitmix_matches_reference
        let mut r = SplitMix64::new(0);
        assert_eq!(r.next_u64(), 0xE220A8397B1DCDAF);
        assert_eq!(r.next_u64(), 0x6E789E6AA1B965F4);
        assert_eq!(r.next_u64(), 0x06C45D188009454F);
    }

    #[test]
    fn fnv_reference_vectors() {
        assert_eq!(fnv1a64(b""), 0xCBF29CE484222325);
        assert_eq!(fnv1a64(b"a"), 0xAF63DC4C8601EC8C);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171F73967E8);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = SplitMix64::new(42);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn exp_mean_close_to_inverse_rate() {
        let mut r = SplitMix64::new(7);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.exp(4.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = SplitMix64::new(9);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal(3.0, 2.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.05, "mean {mean}");
        assert!((var - 4.0).abs() < 0.15, "var {var}");
    }

    #[test]
    fn zipf_is_skewed_and_in_range() {
        let mut r = SplitMix64::new(11);
        let mut counts = [0usize; 8];
        for _ in 0..20_000 {
            counts[r.zipf(8, 1.2)] += 1;
        }
        assert!(counts[0] > counts[3]);
        assert!(counts[3] > counts[7]);
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = SplitMix64::new(1);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn forked_streams_differ() {
        let mut r = SplitMix64::new(5);
        let mut a = r.fork(1);
        let mut b = r.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn chance_extremes() {
        let mut r = SplitMix64::new(3);
        assert!(!(0..1000).any(|_| r.chance(0.0)));
        assert!((0..1000).all(|_| r.chance(1.0)));
    }
}
