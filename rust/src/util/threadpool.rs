//! Worker pool + MPMC channel (tokio is unavailable offline).
//!
//! A deliberately small, predictable substrate: a mutex+condvar MPMC
//! queue with bounded capacity (backpressure for the gateway) and a
//! fixed-size worker pool used by the HTTP server and the batch
//! executors. The serving hot loop itself is single-threaded per model
//! replica (PJRT executables are not Sync), matching the one-engine-per-
//! replica design of the paper's backends.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Error returned by a send on a closed channel.
#[derive(Debug, PartialEq, Eq)]
pub struct SendError;

/// Bounded MPMC channel.
pub struct Channel<T> {
    inner: Arc<ChannelInner<T>>,
}

struct ChannelInner<T> {
    q: Mutex<ChannelState<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    cap: usize,
}

struct ChannelState<T> {
    buf: VecDeque<T>,
    closed: bool,
}

impl<T> Clone for Channel<T> {
    fn clone(&self) -> Self {
        Self { inner: Arc::clone(&self.inner) }
    }
}

impl<T> Channel<T> {
    pub fn bounded(cap: usize) -> Self {
        assert!(cap > 0);
        Self {
            inner: Arc::new(ChannelInner {
                q: Mutex::new(ChannelState { buf: VecDeque::new(), closed: false }),
                not_empty: Condvar::new(),
                not_full: Condvar::new(),
                cap,
            }),
        }
    }

    /// Blocking send; errors if the channel is closed.
    pub fn send(&self, item: T) -> Result<(), SendError> {
        let mut st = self.inner.q.lock().unwrap();
        loop {
            if st.closed {
                return Err(SendError);
            }
            if st.buf.len() < self.inner.cap {
                st.buf.push_back(item);
                self.inner.not_empty.notify_one();
                return Ok(());
            }
            st = self.inner.not_full.wait(st).unwrap();
        }
    }

    /// Non-blocking send; returns the item back if full/closed.
    pub fn try_send(&self, item: T) -> Result<(), T> {
        let mut st = self.inner.q.lock().unwrap();
        if st.closed || st.buf.len() >= self.inner.cap {
            return Err(item);
        }
        st.buf.push_back(item);
        self.inner.not_empty.notify_one();
        Ok(())
    }

    /// Blocking receive; `None` when closed and drained.
    pub fn recv(&self) -> Option<T> {
        let mut st = self.inner.q.lock().unwrap();
        loop {
            if let Some(item) = st.buf.pop_front() {
                self.inner.not_full.notify_one();
                return Some(item);
            }
            if st.closed {
                return None;
            }
            st = self.inner.not_empty.wait(st).unwrap();
        }
    }

    /// Non-blocking receive.
    pub fn try_recv(&self) -> Option<T> {
        let mut st = self.inner.q.lock().unwrap();
        let item = st.buf.pop_front();
        if item.is_some() {
            self.inner.not_full.notify_one();
        }
        item
    }

    /// Drain up to `max` items without blocking (batch collection).
    pub fn drain_up_to(&self, max: usize) -> Vec<T> {
        let mut st = self.inner.q.lock().unwrap();
        let n = st.buf.len().min(max);
        let out: Vec<T> = st.buf.drain(..n).collect();
        if !out.is_empty() {
            self.inner.not_full.notify_all();
        }
        out
    }

    /// Blocking receive with timeout.
    pub fn recv_timeout(&self, timeout: std::time::Duration) -> Option<T> {
        let deadline = std::time::Instant::now() + timeout;
        let mut st = self.inner.q.lock().unwrap();
        loop {
            if let Some(item) = st.buf.pop_front() {
                self.inner.not_full.notify_one();
                return Some(item);
            }
            if st.closed {
                return None;
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, res) = self
                .inner
                .not_empty
                .wait_timeout(st, deadline - now)
                .unwrap();
            st = guard;
            if res.timed_out() && st.buf.is_empty() {
                return None;
            }
        }
    }

    pub fn close(&self) {
        let mut st = self.inner.q.lock().unwrap();
        st.closed = true;
        self.inner.not_empty.notify_all();
        self.inner.not_full.notify_all();
    }

    /// Has the channel been closed? (Buffered items may still remain —
    /// consumers drain them; `recv` returns `None` only when closed
    /// *and* empty.)
    pub fn is_closed(&self) -> bool {
        self.inner.q.lock().unwrap().closed
    }

    pub fn len(&self) -> usize {
        self.inner.q.lock().unwrap().buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size worker pool.
pub struct ThreadPool {
    jobs: Channel<Job>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(threads: usize, name: &str) -> Self {
        assert!(threads > 0);
        let jobs: Channel<Job> = Channel::bounded(4096);
        let workers = (0..threads)
            .map(|i| {
                let rx = jobs.clone();
                std::thread::Builder::new()
                    .name(format!("{name}-{i}"))
                    .spawn(move || {
                        while let Some(job) = rx.recv() {
                            job();
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        Self { jobs, workers }
    }

    /// Submit a job (blocks if the queue is full — natural backpressure).
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.jobs
            .send(Box::new(f))
            .unwrap_or_else(|_| panic!("pool is shut down"));
    }

    pub fn queued(&self) -> usize {
        self.jobs.len()
    }

    /// Close the queue and join all workers.
    pub fn shutdown(mut self) {
        self.jobs.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.jobs.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// A one-shot result slot for request/response rendezvous between the
/// gateway threads and a backend engine (a tiny `oneshot` channel).
pub struct OneShot<T> {
    state: Arc<(Mutex<Option<T>>, Condvar)>,
}

impl<T> Clone for OneShot<T> {
    fn clone(&self) -> Self {
        Self { state: Arc::clone(&self.state) }
    }
}

impl<T> Default for OneShot<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> OneShot<T> {
    pub fn new() -> Self {
        Self { state: Arc::new((Mutex::new(None), Condvar::new())) }
    }

    pub fn put(&self, value: T) {
        let (m, cv) = &*self.state;
        *m.lock().unwrap() = Some(value);
        cv.notify_all();
    }

    pub fn wait(&self) -> T {
        let (m, cv) = &*self.state;
        let mut guard = m.lock().unwrap();
        loop {
            if let Some(v) = guard.take() {
                return v;
            }
            guard = cv.wait(guard).unwrap();
        }
    }

    pub fn wait_timeout(&self, timeout: std::time::Duration) -> Option<T> {
        let (m, cv) = &*self.state;
        let deadline = std::time::Instant::now() + timeout;
        let mut guard = m.lock().unwrap();
        loop {
            if let Some(v) = guard.take() {
                return Some(v);
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return None;
            }
            let (g, _) = cv.wait_timeout(guard, deadline - now).unwrap();
            guard = g;
        }
    }

    /// Non-blocking take: the value if one has been `put`, else `None`.
    /// Lets a poll loop (the gateway's chain state machine) multiplex
    /// many pending rendezvous without parking on any one of them.
    pub fn try_take(&self) -> Option<T> {
        self.state.0.lock().unwrap().take()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn channel_fifo() {
        let ch = Channel::bounded(8);
        ch.send(1).unwrap();
        ch.send(2).unwrap();
        assert_eq!(ch.recv(), Some(1));
        assert_eq!(ch.recv(), Some(2));
    }

    #[test]
    fn channel_close_drains_then_none() {
        let ch = Channel::bounded(8);
        ch.send("a").unwrap();
        assert!(!ch.is_closed());
        ch.close();
        assert!(ch.is_closed());
        assert_eq!(ch.recv(), Some("a"));
        assert_eq!(ch.recv(), None);
        assert_eq!(ch.send("b"), Err(SendError));
    }

    #[test]
    fn try_send_respects_capacity() {
        let ch = Channel::bounded(1);
        assert!(ch.try_send(1).is_ok());
        assert_eq!(ch.try_send(2), Err(2));
    }

    #[test]
    fn drain_up_to_batches() {
        let ch = Channel::bounded(16);
        for i in 0..10 {
            ch.send(i).unwrap();
        }
        let batch = ch.drain_up_to(4);
        assert_eq!(batch, vec![0, 1, 2, 3]);
        assert_eq!(ch.len(), 6);
    }

    #[test]
    fn recv_timeout_expires() {
        let ch: Channel<u32> = Channel::bounded(1);
        let t0 = std::time::Instant::now();
        assert_eq!(ch.recv_timeout(std::time::Duration::from_millis(30)), None);
        assert!(t0.elapsed() >= std::time::Duration::from_millis(25));
    }

    #[test]
    fn pool_runs_jobs_concurrently() {
        let pool = ThreadPool::new(4, "test");
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.shutdown();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn oneshot_rendezvous() {
        let slot = OneShot::new();
        let slot2 = slot.clone();
        let h = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(10));
            slot2.put(99);
        });
        assert_eq!(slot.wait(), 99);
        h.join().unwrap();
    }

    #[test]
    fn oneshot_timeout() {
        let slot: OneShot<u8> = OneShot::new();
        assert_eq!(
            slot.wait_timeout(std::time::Duration::from_millis(20)),
            None
        );
    }

    #[test]
    fn oneshot_try_take() {
        let slot: OneShot<u8> = OneShot::new();
        assert_eq!(slot.try_take(), None);
        slot.put(7);
        assert_eq!(slot.try_take(), Some(7));
        assert_eq!(slot.try_take(), None, "one-shot: a value takes once");
    }

    #[test]
    fn mpmc_many_producers_consumers() {
        let ch = Channel::bounded(4);
        let consumed = Arc::new(AtomicUsize::new(0));
        let mut handles = vec![];
        for _ in 0..3 {
            let rx = ch.clone();
            let c = Arc::clone(&consumed);
            handles.push(std::thread::spawn(move || {
                while rx.recv().is_some() {
                    c.fetch_add(1, Ordering::SeqCst);
                }
            }));
        }
        for _ in 0..4 {
            let tx = ch.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..50 {
                    tx.send(i).unwrap();
                }
            }));
        }
        std::thread::sleep(std::time::Duration::from_millis(100));
        ch.close();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(consumed.load(Ordering::SeqCst), 200);
    }
}
