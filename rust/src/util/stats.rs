//! Statistics substrate: summaries, percentiles, EMA, rolling windows,
//! histograms, and the min–max normalizers the scoring layer (Eq. 2) and
//! the paper's Eq. 10 radar normalization use.

/// Summary statistics over a sample.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    pub count: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary::default();
        }
        let mut sorted: Vec<f64> = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = sorted.len();
        let mean = sorted.iter().sum::<f64>() / n as f64;
        let var = sorted.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
            / n as f64;
        Summary {
            count: n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile_sorted(&sorted, 50.0),
            p95: percentile_sorted(&sorted, 95.0),
            p99: percentile_sorted(&sorted, 99.0),
        }
    }
}

/// Percentile by linear interpolation over a sorted slice.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] + (sorted[hi] - sorted[lo]) * frac
}

/// Percentile of an unsorted sample.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    let mut sorted: Vec<f64> = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&sorted, p)
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Min–max normalization to [0, 1]; constant inputs map to 0.5 (neutral).
pub fn minmax_norm(x: f64, min: f64, max: f64) -> f64 {
    if max <= min {
        0.5
    } else {
        ((x - min) / (max - min)).clamp(0.0, 1.0)
    }
}

/// The paper's Eq. 10: `N_i = 10 * (x_i - min) / (max - min)`.
pub fn eq10_scale(xs: &[f64]) -> Vec<f64> {
    if xs.is_empty() {
        return vec![];
    }
    let min = xs.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    xs.iter().map(|&x| 10.0 * minmax_norm(x, min, max)).collect()
}

/// Exponential moving average.
#[derive(Debug, Clone)]
pub struct Ema {
    alpha: f64,
    value: Option<f64>,
}

impl Ema {
    pub fn new(alpha: f64) -> Self {
        Self { alpha, value: None }
    }

    pub fn observe(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(prev) => self.alpha * x + (1.0 - self.alpha) * prev,
        };
        self.value = Some(v);
        v
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }

    pub fn get_or(&self, default: f64) -> f64 {
        self.value.unwrap_or(default)
    }
}

/// Fixed-capacity rolling window (ring buffer) of observations.
#[derive(Debug, Clone)]
pub struct Rolling {
    buf: Vec<f64>,
    cap: usize,
    head: usize,
    full: bool,
}

impl Rolling {
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0);
        Self { buf: Vec::with_capacity(cap), cap, head: 0, full: false }
    }

    pub fn push(&mut self, x: f64) {
        if self.buf.len() < self.cap {
            self.buf.push(x);
            if self.buf.len() == self.cap {
                self.full = true;
            }
        } else {
            self.buf[self.head] = x;
            self.head = (self.head + 1) % self.cap;
        }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn mean(&self) -> f64 {
        mean(&self.buf)
    }

    pub fn min(&self) -> f64 {
        self.buf.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.buf.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn values(&self) -> &[f64] {
        &self.buf
    }

    pub fn summary(&self) -> Summary {
        Summary::of(&self.buf)
    }
}

/// Streaming normalizer over historical observations — the paper's
/// "min–max or distributional normalization computed over historical
/// system statistics" for T̂ and Ĉ in Eq. 2.
#[derive(Debug, Clone)]
pub struct HistoryNorm {
    window: Rolling,
}

impl HistoryNorm {
    pub fn new(window: usize) -> Self {
        Self { window: Rolling::new(window) }
    }

    /// Record an observation and return its normalized *badness* in [0,1]
    /// relative to history (0 = best seen, 1 = worst seen).
    pub fn observe(&mut self, x: f64) -> f64 {
        self.window.push(x);
        self.normalize(x)
    }

    /// Normalize without recording.
    pub fn normalize(&self, x: f64) -> f64 {
        if self.window.len() < 2 {
            return 0.5;
        }
        minmax_norm(x, self.window.min(), self.window.max())
    }

    pub fn len(&self) -> usize {
        self.window.len()
    }

    pub fn is_empty(&self) -> bool {
        self.window.is_empty()
    }
}

/// Simple linear-bucket histogram for latency distributions.
#[derive(Debug, Clone)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub buckets: Vec<u64>,
    pub underflow: u64,
    pub overflow: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, n: usize) -> Self {
        assert!(hi > lo && n > 0);
        Self { lo, hi, buckets: vec![0; n], underflow: 0, overflow: 0 }
    }

    pub fn add(&mut self, x: f64) {
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let n = self.buckets.len();
            let idx = ((x - self.lo) / (self.hi - self.lo) * n as f64) as usize;
            self.buckets[idx.min(n - 1)] += 1;
        }
    }

    pub fn total(&self) -> u64 {
        self.buckets.iter().sum::<u64>() + self.underflow + self.overflow
    }

    /// ASCII sparkline for report output.
    pub fn sparkline(&self) -> String {
        const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        let max = self.buckets.iter().copied().max().unwrap_or(0).max(1);
        self.buckets
            .iter()
            .map(|&c| BARS[(c * 7 / max) as usize])
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.count, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn summary_empty() {
        let s = Summary::of(&[]);
        assert_eq!(s.count, 0);
        assert_eq!(s.mean, 0.0);
    }

    #[test]
    fn percentiles_interpolate() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert!((percentile(&xs, 50.0) - 50.5).abs() < 1e-9);
        assert!((percentile(&xs, 99.0) - 99.01).abs() < 0.02);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
    }

    #[test]
    fn minmax_norm_clamps_and_degenerates() {
        assert_eq!(minmax_norm(5.0, 0.0, 10.0), 0.5);
        assert_eq!(minmax_norm(-1.0, 0.0, 10.0), 0.0);
        assert_eq!(minmax_norm(11.0, 0.0, 10.0), 1.0);
        assert_eq!(minmax_norm(3.0, 2.0, 2.0), 0.5);
    }

    #[test]
    fn eq10_matches_paper_form() {
        let v = eq10_scale(&[2.0, 4.0, 6.0]);
        assert_eq!(v, vec![0.0, 5.0, 10.0]);
    }

    #[test]
    fn ema_converges() {
        let mut e = Ema::new(0.5);
        assert_eq!(e.observe(10.0), 10.0);
        let v = e.observe(0.0);
        assert!((v - 5.0).abs() < 1e-12);
        for _ in 0..64 {
            e.observe(3.0);
        }
        assert!((e.get().unwrap() - 3.0).abs() < 1e-6);
    }

    #[test]
    fn rolling_evicts_oldest() {
        let mut r = Rolling::new(3);
        for x in [1.0, 2.0, 3.0, 4.0] {
            r.push(x);
        }
        assert_eq!(r.len(), 3);
        assert_eq!(r.min(), 2.0);
        assert_eq!(r.max(), 4.0);
    }

    #[test]
    fn history_norm_tracks_window() {
        let mut h = HistoryNorm::new(8);
        assert_eq!(h.normalize(1.0), 0.5); // no history yet
        h.observe(0.0);
        h.observe(10.0);
        assert!((h.normalize(5.0) - 0.5).abs() < 1e-12);
        assert_eq!(h.normalize(0.0), 0.0);
        assert_eq!(h.normalize(10.0), 1.0);
    }

    #[test]
    fn histogram_counts() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for x in [0.5, 1.5, 9.9, -1.0, 10.0] {
            h.add(x);
        }
        assert_eq!(h.buckets[0], 1);
        assert_eq!(h.buckets[1], 1);
        assert_eq!(h.buckets[9], 1);
        assert_eq!(h.underflow, 1);
        assert_eq!(h.overflow, 1);
        assert_eq!(h.total(), 5);
        assert_eq!(h.sparkline().chars().count(), 10);
    }
}
