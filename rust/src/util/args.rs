//! CLI argument parser (clap is unavailable offline).
//!
//! Supports `command [--flag] [--key value] [positional...]` with typed
//! accessors and generated usage text — enough surface for the
//! `pick-and-spin` binary and the bench harnesses.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// Parsed command line.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub command: Option<String>,
    pub flags: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

/// Declarative spec used for parsing + usage text.
#[derive(Debug, Clone)]
pub struct Spec {
    pub name: &'static str,
    pub about: &'static str,
    /// (name, takes_value, help)
    pub options: Vec<(&'static str, bool, &'static str)>,
}

impl Spec {
    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nOptions:\n", self.name, self.about);
        for (name, takes_value, help) in &self.options {
            let arg = if *takes_value {
                format!("--{name} <value>")
            } else {
                format!("--{name}")
            };
            s.push_str(&format!("  {arg:<28} {help}\n"));
        }
        s
    }

    /// Parse argv (excluding the program name and command).
    pub fn parse(&self, argv: &[String]) -> Result<Args> {
        let mut args = Args::default();
        let known: BTreeMap<&str, bool> =
            self.options.iter().map(|(n, tv, _)| (*n, *tv)).collect();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                // --key=value form
                if let Some((k, v)) = name.split_once('=') {
                    match known.get(k) {
                        Some(true) => {
                            args.options.insert(k.to_string(), v.to_string());
                        }
                        Some(false) => bail!("--{k} does not take a value"),
                        None => bail!("unknown option --{k}\n\n{}", self.usage()),
                    }
                } else {
                    match known.get(name) {
                        Some(true) => {
                            i += 1;
                            let v = argv.get(i).ok_or_else(|| {
                                anyhow!("--{name} requires a value")
                            })?;
                            args.options.insert(name.to_string(), v.clone());
                        }
                        Some(false) => args.flags.push(name.to_string()),
                        None => bail!("unknown option --{name}\n\n{}", self.usage()),
                    }
                }
            } else {
                args.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(args)
    }
}

impl Args {
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn opt_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.opt(name).unwrap_or(default)
    }

    pub fn opt_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name}: `{v}` is not an integer")),
        }
    }

    pub fn opt_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name}: `{v}` is not a number")),
        }
    }

    pub fn opt_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name}: `{v}` is not an integer")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> Spec {
        Spec {
            name: "test",
            about: "test spec",
            options: vec![
                ("count", true, "how many"),
                ("verbose", false, "chatty"),
                ("rate", true, "qps"),
            ],
        }
    }

    fn sv(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parse_mixed() {
        let a = spec()
            .parse(&sv(&["--count", "5", "--verbose", "pos1", "--rate=2.5"]))
            .unwrap();
        assert_eq!(a.opt_usize("count", 0).unwrap(), 5);
        assert!(a.flag("verbose"));
        assert_eq!(a.opt_f64("rate", 0.0).unwrap(), 2.5);
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(spec().parse(&sv(&["--nope"])).is_err());
    }

    #[test]
    fn missing_value_rejected() {
        assert!(spec().parse(&sv(&["--count"])).is_err());
    }

    #[test]
    fn flag_with_value_rejected() {
        assert!(spec().parse(&sv(&["--verbose=1"])).is_err());
    }

    #[test]
    fn defaults_apply() {
        let a = spec().parse(&sv(&[])).unwrap();
        assert_eq!(a.opt_usize("count", 7).unwrap(), 7);
        assert_eq!(a.opt_or("missing", "x"), "x");
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn bad_number_errors() {
        let a = spec().parse(&sv(&["--count", "abc"])).unwrap();
        assert!(a.opt_usize("count", 0).is_err());
    }

    #[test]
    fn usage_mentions_options() {
        let u = spec().usage();
        assert!(u.contains("--count"));
        assert!(u.contains("--verbose"));
    }
}
