//! Shared bench-harness support (criterion is unavailable offline):
//! experiment runners over the real template library + a tiny
//! measure/report toolkit for the hot-path microbenches.

use pick_and_spin::baselines::SelectionPolicy;
use pick_and_spin::config::{Profile, RouterMode};
use pick_and_spin::sim::{run, Deployment, SimConfig, SimReport};
use pick_and_spin::workload::{OracleClassifier, TemplateLibrary};

pub const SEED: u64 = 42;

/// Load the real 8-benchmark template library (requires `make artifacts`
/// to have written data/templates.json).
pub fn library() -> TemplateLibrary {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/data/templates.json");
    TemplateLibrary::load(path).expect(
        "data/templates.json missing — run `make artifacts` first",
    )
}

/// The real library when built, else the built-in synthetic stand-in —
/// for sections (the pinned routing bench) that must run in CI, where
/// `make artifacts` hasn't happened.
pub fn library_or_synthetic() -> TemplateLibrary {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/data/templates.json");
    if std::path::Path::new(path).exists() {
        TemplateLibrary::load(path).expect("templates.json parse")
    } else {
        TemplateLibrary::synthetic()
    }
}

/// Experiment-scale knobs: requests per simulated run. The full paper
/// scale (155,095 runs) is the default for `paper_tables`; set
/// PS_BENCH_QUICK=1 for CI-speed runs.
pub fn n_requests() -> usize {
    if std::env::var("PS_BENCH_QUICK").is_ok() {
        8_000
    } else {
        155_095
    }
}

/// Canonical experiment configurations. Rates are calibrated to the
/// 8×8-GPU simulated cluster so the baseline is ~70% utilized, matching
/// the paper's non-saturated testbed.
pub fn base_config(n: usize) -> SimConfig {
    let mut sc = SimConfig::defaults();
    sc.n_requests = n;
    sc.rate_qps = 4.0;
    sc.seed = SEED;
    sc.cluster.nodes = 8;
    sc
}

pub fn static_baseline(n: usize) -> SimConfig {
    let mut sc = base_config(n);
    sc.deployment = Deployment::Static;
    sc.policy = SelectionPolicy::RoundRobin;
    sc.router_mode = RouterMode::Keyword; // routing unused by round-robin
    sc
}

pub fn routed(n: usize, router: RouterMode, policy: SelectionPolicy) -> SimConfig {
    let mut sc = base_config(n);
    sc.deployment = Deployment::Dynamic { auto_recovery: false };
    sc.policy = policy;
    sc.router_mode = router;
    sc.profile = Profile::BALANCED;
    // Routed configs run hotter (the paper's routed experiments hold
    // 60–70% utilization): double the offered load and let the scaler
    // pack replicas tighter than the conservative default.
    sc.rate_qps = 8.0;
    sc.orchestrator.target_concurrency = 8.0;
    sc.orchestrator.idle_timeout_s = 60.0;
    sc
}

/// Run a sim config against the oracle classifier (error rate matching
/// the compiled classifier's measured validation error).
pub fn simulate(lib: &TemplateLibrary, sc: &SimConfig) -> SimReport {
    let cls = Box::new(OracleClassifier::new(
        lib.clone(),
        sc.classifier_error,
        sc.seed ^ 0xC1A5,
    ));
    run(sc, lib, cls).expect("simulation failed")
}

/// Wall-clock measurement helper for the hot-path microbenches.
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub total_s: f64,
}

impl Measurement {
    pub fn per_iter_us(&self) -> f64 {
        self.total_s / self.iters as f64 * 1e6
    }

    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>10} iters  {:>12.3} µs/iter  {:>10.1} ops/s",
            self.name,
            self.iters,
            self.per_iter_us(),
            self.iters as f64 / self.total_s
        )
    }
}

/// Measure a closure: warm up, then time `iters` runs.
pub fn measure<F: FnMut()>(name: &str, iters: usize, mut f: F) -> Measurement {
    for _ in 0..iters.min(16) {
        f();
    }
    let t0 = std::time::Instant::now();
    for _ in 0..iters {
        f();
    }
    Measurement {
        name: name.to_string(),
        iters,
        total_s: t0.elapsed().as_secs_f64(),
    }
}

/// Which sections to run: `cargo bench --bench X -- table1 fig4 ...`
/// (no args = all).
pub fn selected(section: &str) -> bool {
    let args: Vec<String> = std::env::args().skip(1)
        .filter(|a| !a.starts_with('-')).collect();
    args.is_empty() || args.iter().any(|a| a == section)
}
