//! Regenerates every *figure* in the paper's evaluation section as data
//! series (values + ASCII sparklines — the same rows/series the paper
//! plots).
//!
//! ```text
//! cargo bench --bench paper_figures             # all figures
//! cargo bench --bench paper_figures -- fig10    # one section
//! ```
//!
//! Figs. 10/11 (TTFT) run *live* through the compiled PJRT artifacts when
//! `artifacts/` exists; everything else uses the virtual-time simulator
//! at paper scale.

mod common;

use common::{base_config, library, n_requests, routed, selected, simulate};
use pick_and_spin::baselines::SelectionPolicy;
use pick_and_spin::config::RouterMode;
use pick_and_spin::eval;
use pick_and_spin::sim::Deployment;
use pick_and_spin::util::format_table;
use pick_and_spin::util::stats::Histogram;

fn main() {
    let lib = library();
    let n = (n_requests() / 5).max(6000); // 31,019-prompt scale per router
    println!("# paper figures — data series\n");

    if selected("fig4") {
        println!("## Fig. 4 — complexity distributions, keyword vs DistilBERT\n");
        let kw = simulate(&lib, &routed(n, RouterMode::Keyword,
                                        SelectionPolicy::TierDirected));
        let sem = simulate(&lib, &routed(n, RouterMode::Semantic,
                                         SelectionPolicy::TierDirected));
        let dk = eval::complexity_distribution(&kw.records);
        let ds = eval::complexity_distribution(&sem.records);
        let truth: [usize; 3] = {
            let mut t = [0; 3];
            for r in &kw.records {
                t[r.true_complexity] += 1;
            }
            t
        };
        println!("{}", format_table(
            &["Class", "Keyword", "DistilBERT", "Ground truth"],
            &(0..3).map(|c| vec![
                ["low", "medium", "high"][c].to_string(),
                dk[c].to_string(),
                ds[c].to_string(),
                truth[c].to_string(),
            ]).collect::<Vec<_>>(),
        ));
        println!("keyword routing accuracy   {:.1}%", kw.routing_accuracy() * 100.0);
        println!("semantic routing accuracy  {:.1}%  (paper: clear separation)\n",
                 sem.routing_accuracy() * 100.0);
    }

    if selected("fig5") || selected("fig6") {
        println!("## Figs. 5/6 — per-benchmark success rate and latency\n");
        let kw = simulate(&lib, &routed(n, RouterMode::Keyword,
                                        SelectionPolicy::TierDirected));
        let sem = simulate(&lib, &routed(n, RouterMode::Semantic,
                                         SelectionPolicy::TierDirected));
        let kw_rows = eval::per_benchmark_rows(&kw);
        let sem_rows = eval::per_benchmark_rows(&sem);
        let mut rows = Vec::new();
        for (name, ks, kl) in &kw_rows {
            if let Some((_, ss, sl)) = sem_rows.iter().find(|(n2, _, _)| n2 == name) {
                rows.push(vec![
                    name.clone(),
                    format!("{ks:.1}"),
                    format!("{ss:.1}"),
                    format!("{kl:.1}"),
                    format!("{sl:.1}"),
                ]);
            }
        }
        println!("{}", format_table(
            &["Benchmark", "KW succ %", "DB succ %", "KW lat (s)", "DB lat (s)"],
            &rows,
        ));
        println!("(paper: DistilBERT higher success on reasoning-heavy \
                  benchmarks; keyword faster)\n");
    }

    if selected("fig7") {
        println!("## Fig. 7 — accuracy–latency tradeoff (router × profile)\n");
        let mut pts = Vec::new();
        for router in [RouterMode::Keyword, RouterMode::Semantic, RouterMode::Hybrid] {
            for profile in [pick_and_spin::config::Profile::QUALITY,
                            pick_and_spin::config::Profile::SPEED,
                            pick_and_spin::config::Profile::BALANCED] {
                let mut sc = routed(n / 3, router, SelectionPolicy::MultiObjective);
                sc.profile = profile;
                let rep = simulate(&lib, &sc);
                pts.push(vec![
                    format!("{}/{}", router.name(), profile.name),
                    format!("{:.1}", rep.success_rate() * 100.0),
                    format!("{:.1}", rep.mean_latency_s()),
                ]);
            }
        }
        println!("{}", format_table(&["Config", "Accuracy (%)", "Latency (s)"], &pts));
    }

    if selected("fig8") {
        println!("## Fig. 8 — cost & latency overhead, static vs dynamic\n");
        let nn = (n / 2).max(4000);
        let mut stat_cfg = base_config(nn);
        stat_cfg.deployment = Deployment::Static;
        stat_cfg.policy = SelectionPolicy::RoundRobin;
        stat_cfg.rate_qps = 3.0;
        let stat = simulate(&lib, &stat_cfg);
        let mut dyn_cfg = routed(nn, RouterMode::Hybrid, SelectionPolicy::MultiObjective);
        dyn_cfg.rate_qps = 3.0;
        let dynamic = simulate(&lib, &dyn_cfg);
        println!("{}", format_table(
            &["Orchestration", "Cost/query (USD)", "Mean latency (s)", "GPU util (%)"],
            &[
                vec!["Static".into(),
                     format!("{:.4}", stat.cost_per_query_usd()),
                     format!("{:.1}", stat.mean_latency_s()),
                     format!("{:.1}", stat.gpu_utilization() * 100.0)],
                vec!["Dynamic (PS)".into(),
                     format!("{:.4}", dynamic.cost_per_query_usd()),
                     format!("{:.1}", dynamic.mean_latency_s()),
                     format!("{:.1}", dynamic.gpu_utilization() * 100.0)],
            ],
        ));
        println!("(paper: ~1/3 cost reduction from on-demand scaling)\n");
    }

    if selected("fig9") {
        println!("## Fig. 9 — five normalized dimensions (Eq. 10)\n");
        let kw = simulate(&lib, &routed(n, RouterMode::Keyword,
                                        SelectionPolicy::TierDirected));
        let sem = simulate(&lib, &routed(n, RouterMode::Semantic,
                                         SelectionPolicy::TierDirected));
        let rows = eval::radar(&[("Keyword", &kw), ("DistilBERT", &sem)]);
        println!("{}", format_table(
            &["System", "Accuracy", "Latency", "Scalability", "Utilization", "Robustness"],
            &rows.iter().map(|(name, d)| {
                let mut row = vec![name.clone()];
                row.extend(d.iter().map(|v| format!("{v:.1}")));
                row
            }).collect::<Vec<_>>(),
        ));
        println!("(paper: keyword wins latency/utilization, DistilBERT wins \
                  accuracy/robustness)\n");
    }

    if selected("fig10") || selected("fig11") {
        println!("## Figs. 10/11 — TTFT median and percentiles\n");
        // Simulated (paper-scale) TTFT:
        let kw = simulate(&lib, &routed(n, RouterMode::Keyword,
                                        SelectionPolicy::TierDirected));
        let sem = simulate(&lib, &routed(n, RouterMode::Semantic,
                                         SelectionPolicy::TierDirected));
        let ks = eval::ttft_summary(&kw);
        let ss = eval::ttft_summary(&sem);
        println!("{}", format_table(
            &["Router", "P50 (s)", "P95 (s)", "P99 (s)"],
            &[
                vec!["Keyword".into(), format!("{:.2}", ks.p50),
                     format!("{:.2}", ks.p95), format!("{:.2}", ks.p99)],
                vec!["DistilBERT".into(), format!("{:.2}", ss.p50),
                     format!("{:.2}", ss.p95), format!("{:.2}", ss.p99)],
            ],
        ));
        let delta = (ss.p50 / ks.p50 - 1.0) * 100.0;
        println!("median TTFT increase from semantic classification: {delta:.1}% \
                  (paper: +23.5%)\n");
        let mut hist = Histogram::new(0.0, ks.p99.max(ss.p99), 40);
        for r in &kw.records {
            hist.add(r.ttft_s);
        }
        println!("keyword TTFT distribution:    {}", hist.sparkline());
        let mut hist2 = Histogram::new(0.0, ks.p99.max(ss.p99), 40);
        for r in &sem.records {
            hist2.add(r.ttft_s);
        }
        println!("distilbert TTFT distribution: {}\n", hist2.sparkline());

        // Live TTFT through the compiled artifacts (small N):
        let artifacts = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        if std::path::Path::new(&format!("{artifacts}/manifest.json")).exists()
            && selected("fig10")
        {
            live_ttft(artifacts, &lib);
        }
    }

    if selected("scaling") {
        println!("## Scalability — throughput under 10→1000 QPS offered load\n");
        // Sim arrival rates sweep; recovery injections at each level.
        for qps in [10.0, 50.0, 100.0, 500.0, 1000.0] {
            let mut sc = routed(8000, RouterMode::Hybrid, SelectionPolicy::MultiObjective);
            sc.rate_qps = qps;
            sc.cluster.nodes = 64; // scale the substrate with offered load
            sc.orchestrator.max_replicas = 64;
            sc.fail_every_s = Some(200.0);
            let rep = simulate(&lib, &sc);
            println!(
                "offered {qps:>6.0} qps → served {:>7.1} qps  success {:>5.1}%  \
                 recovery {}",
                rep.throughput_qps(),
                rep.success_rate() * 100.0,
                rep.mean_recovery_s
                    .map(|s| format!("{s:.1}s"))
                    .unwrap_or_else(|| "-".into()),
            );
        }
        println!("(paper: linear throughput scaling, recovery < 5 s)\n");
    }

    println!("done.");
}

/// Live TTFT measurement through the real compiled stack.
fn live_ttft(artifacts: &str, lib: &pick_and_spin::workload::TemplateLibrary) {
    use pick_and_spin::runtime::Runtime;
    use pick_and_spin::workload::Generator;

    println!("### live TTFT (compiled PJRT path, small N)\n");
    let mut rt = match Runtime::load(artifacts) {
        Ok(rt) => rt,
        Err(e) => {
            println!("(skipped: {e:#})");
            return;
        }
    };
    let engines: Vec<_> = ["small", "medium", "large"]
        .iter()
        .map(|t| rt.lm_engine(t, &[1]).expect("engine"))
        .collect();
    let mut cls = rt.classifier_engine().expect("classifier");
    let mut gen = Generator::new(lib, 7);
    let mut rows = Vec::new();
    for (mode, use_semantic) in [("keyword", false), ("distilbert", true)] {
        let mut ttfts = Vec::new();
        for i in 0..30u64 {
            let req = gen.request(i, 0.0);
            let t0 = std::time::Instant::now();
            let class = if use_semantic {
                use pick_and_spin::router::Classifier;
                cls.classify(&req.prompt).map(|(c, _)| c).unwrap_or(1)
            } else {
                pick_and_spin::router::keyword::KeywordRouter::classify(&req.prompt)
                    .complexity
            };
            let engine = &engines[class.min(2)];
            let g = engine.generate(&req.prompt, 4).expect("generate");
            ttfts.push(t0.elapsed().as_secs_f64() - g.latency_s + g.ttft_s
                + (t0.elapsed().as_secs_f64() - g.latency_s).max(0.0));
        }
        let s = pick_and_spin::util::stats::Summary::of(&ttfts);
        rows.push(vec![
            mode.to_string(),
            format!("{:.2}", s.p50 * 1000.0),
            format!("{:.2}", s.p95 * 1000.0),
            format!("{:.2}", s.p99 * 1000.0),
        ]);
    }
    println!("{}", format_table(
        &["Router (live)", "P50 (ms)", "P95 (ms)", "P99 (ms)"], &rows));
    println!("(classification adds measurable TTFT on the live path, the \
              paper's Fig. 10 effect at compiled-artifact scale)\n");
}
