//! Regenerates every *table* in the paper's evaluation section.
//!
//! ```text
//! cargo bench --bench paper_tables              # all tables
//! cargo bench --bench paper_tables -- table3    # one section
//! PS_BENCH_QUICK=1 cargo bench ...              # CI-speed subsample
//! ```
//!
//! Absolute numbers come from a simulated substrate (see DESIGN.md
//! §Substitutions); the *shape* — who wins, by what factor — is the
//! reproduction target.

mod common;

use common::{base_config, library, n_requests, routed, selected, simulate,
             static_baseline};
use pick_and_spin::baselines::SelectionPolicy;
use pick_and_spin::config::RouterMode;
use pick_and_spin::eval;
use pick_and_spin::models::completion::TABLE1_RATES;
use pick_and_spin::sim::Deployment;

fn main() {
    let lib = library();
    let n = n_requests();
    println!("# paper tables — {n} simulated runs per configuration\n");

    if selected("table1") {
        println!("## Table 1 — baseline inference completion\n");
        let t0 = std::time::Instant::now();
        let base = simulate(&lib, &static_baseline(n));
        println!("{}", eval::table1(&base, &TABLE1_RATES));
        println!(
            "(paper total 77.1% — note its printed total row, 163,720, \
             differs from its own column sum of 155,095; we reproduce the \
             per-benchmark rows)  [{:.1}s]\n",
            t0.elapsed().as_secs_f64()
        );
    }

    if selected("table2") {
        println!("## Table 2 — routing performance (vs unrouted baseline)\n");
        let nn = n / 4;
        let base = simulate(&lib, &static_baseline(nn));
        let kw = simulate(
            &lib,
            &routed(nn, RouterMode::Keyword, SelectionPolicy::TierDirected),
        );
        let sem = simulate(
            &lib,
            &routed(nn, RouterMode::Semantic, SelectionPolicy::TierDirected),
        );
        let rows = vec![
            eval::routing_row("Keyword based", &kw, &base),
            eval::routing_row("DistilBERT based", &sem, &base),
        ];
        println!("{}", eval::table2(&rows));
        println!(
            "(paper: keyword +4.8% acc / 21.5% lat↓ / 62.3% util; \
             DistilBERT +8.6% / 27.4% / 68.9%)\n"
        );
    }

    if selected("table3") {
        println!("## Table 3 — model-backend selection strategies\n");
        let nn = n / 4;
        let rand = simulate(
            &lib,
            &routed(nn, RouterMode::Hybrid, SelectionPolicy::Random),
        );
        let lat = simulate(
            &lib,
            &routed(nn, RouterMode::Hybrid, SelectionPolicy::LatencyOnly),
        );
        let multi = simulate(
            &lib,
            &routed(nn, RouterMode::Hybrid, SelectionPolicy::MultiObjective),
        );
        println!(
            "{}",
            eval::table3(&[
                ("Random assignment", &rand),
                ("Latency only", &lat),
                ("Multi objective", &multi),
            ])
        );
        println!(
            "(paper: 78.4%/63.1s/$0.020 → 82.9%/48.6s/$0.017 → \
             88.3%/42.5s/$0.015, +21.7%)\n"
        );
        // η compares routed vs baseline accuracy-per-cost at matched
        // (light) load, where the orchestration savings live (Eq. 9).
        let mut eta_base = static_baseline(nn / 2);
        eta_base.rate_qps = 3.0;
        let mut eta_routed = routed(nn / 2, RouterMode::Hybrid,
                                    SelectionPolicy::MultiObjective);
        eta_routed.rate_qps = 3.0;
        let eb = simulate(&lib, &eta_base);
        let er = simulate(&lib, &eta_routed);
        println!(
            "η (Eq. 9) = {:.2}   (paper: 1.43)\n",
            eval::eta(&er, &eb)
        );
    }

    if selected("table4") {
        println!("## Table 4 — cost & recovery, static vs dynamic\n");
        let nn = (n / 8).max(4000);
        let mk = |deployment, policy| {
            let mut sc = base_config(nn);
            sc.deployment = deployment;
            sc.policy = policy;
            sc.fail_every_s = Some(300.0);
            sc.cluster.pvc_bandwidth_gbps = 3.0;
            // Bursty demand is where scale-to-zero pays: high phases keep
            // warm capacity, low phases shed it; the static deployment
            // burns idle GPUs throughout.
            sc.rate_qps = 3.0;
            sc.bursty = Some((6.0, 0.15, 300.0));
            sc.orchestrator.target_concurrency = 10.0;
            sc.orchestrator.idle_timeout_s = 45.0;
            sc.orchestrator.max_replicas = 2;
            sc.static_replicas = 2; // static must provision for the peak
            sc
        };
        let stat = simulate(&lib, &mk(Deployment::Static, SelectionPolicy::RoundRobin));
        let base = simulate(
            &lib,
            &mk(Deployment::Dynamic { auto_recovery: false },
                SelectionPolicy::MultiObjective),
        );
        let auto = simulate(
            &lib,
            &mk(Deployment::Dynamic { auto_recovery: true },
                SelectionPolicy::MultiObjective),
        );
        println!(
            "{}",
            eval::table4(&[
                ("Static deployment", &stat),
                ("Pick and Spin (base)", &base),
                ("Pick and Spin (auto)", &auto),
            ])
        );
        println!(
            "(paper: $0.021/45s → $0.016/12s → $0.014/4s; the reproduction \
             target is the ordering and ~1.3–1.5× cost gap and ~4–10× \
             recovery gap)\n"
        );
    }

    if selected("ablations") {
        println!("## Ablations (beyond the paper's tables)\n");
        let nn = (n / 16).max(2000);
        println!("### warm-pool size sweep (tier floors, cost vs p95 wait)\n");
        for warm in [[0, 0, 0], [1, 0, 0], [1, 1, 0], [2, 2, 1]] {
            let mut sc = routed(nn, RouterMode::Hybrid, SelectionPolicy::MultiObjective);
            sc.orchestrator.warm_pool = warm;
            sc.bursty = Some((8.0, 0.5, 120.0));
            let rep = simulate(&lib, &sc);
            let waits: Vec<f64> = rep.records.iter().map(|r| r.wait_s).collect();
            println!(
                "warm {warm:?}: cost/query ${:.4}  p95 wait {:.1}s  success {:.1}%",
                rep.cost_per_query_usd(),
                pick_and_spin::util::stats::percentile(&waits, 95.0),
                rep.success_rate() * 100.0
            );
        }
        println!("\n### cooldown τ sweep (scaling stability)\n");
        for cooldown in [5.0, 30.0, 120.0] {
            let mut sc = routed(nn, RouterMode::Hybrid, SelectionPolicy::MultiObjective);
            sc.orchestrator.cooldown_s = cooldown;
            sc.bursty = Some((8.0, 0.5, 120.0));
            let rep = simulate(&lib, &sc);
            println!(
                "cooldown {cooldown:>5.0}s: cost/query ${:.4}  mean latency {:.1}s",
                rep.cost_per_query_usd(),
                rep.mean_latency_s()
            );
        }
        println!("\n### hybrid confidence threshold sweep\n");
        for _thresh in [0.4, 0.65, 0.9] {
            // The hybrid threshold lives in RouterConfig::default() inside
            // the sim; sweep via routing accuracy proxy at equal load.
            let sc = routed(nn, RouterMode::Hybrid, SelectionPolicy::MultiObjective);
            let rep = simulate(&lib, &sc);
            println!(
                "hybrid: routing accuracy {:.1}%  success {:.1}%",
                rep.routing_accuracy() * 100.0,
                rep.success_rate() * 100.0
            );
            break; // single config (threshold plumbed in sim config v2)
        }
    }

    println!("done.");
}
