//! Hot-path microbenchmarks — the §Perf numbers for L3 (and the live
//! PJRT path when artifacts exist).
//!
//! Targets (DESIGN.md §Perf): keyword routing < 50 µs, matrix selection
//! < 10 µs, simulator ≥ 1M events/s equivalent, tokenizer > 1M words/s.

mod common;

use common::{base_config, library, library_or_synthetic, measure, selected, simulate, routed};
use pick_and_spin::baselines::SelectionPolicy;
use pick_and_spin::config::{Profile, RouterMode};
use pick_and_spin::models::zoo;
use pick_and_spin::orchestrator::select;
use pick_and_spin::registry::Registry;
use pick_and_spin::router::keyword::KeywordRouter;
use pick_and_spin::router::Classification;
use pick_and_spin::scoring::Weights;
use pick_and_spin::tokenizer;
use pick_and_spin::workload::Generator;

fn main() {
    println!("# hot-path microbenchmarks\n");
    // The template library needs `make artifacts`; sections that run
    // without it (kv/pool/prefix/selection) must not force the load, so
    // CI can run them standalone.
    let need_lib = ["router", "tokenizer", "classifier", "sim"]
        .iter()
        .any(|s| selected(s));
    let lib = need_lib.then(library);
    let prompts: Vec<String> = lib
        .as_ref()
        .map(|l| {
            let mut gen = Generator::new(l, 3);
            (0..512).map(|_| gen.prompt_mixed().text).collect()
        })
        .unwrap_or_default();

    if selected("router") {
        let mut i = 0;
        let m = measure("keyword route", 200_000, || {
            let _ = KeywordRouter::classify(&prompts[i % prompts.len()]);
            i += 1;
        });
        println!("{}", m.report());
        assert!(m.per_iter_us() < 50.0, "keyword routing too slow");
    }

    if selected("tokenizer") {
        let mut i = 0;
        let m = measure("tokenizer encode (seq 48)", 200_000, || {
            let _ = tokenizer::encode(&prompts[i % prompts.len()], 48);
            i += 1;
        });
        println!("{}", m.report());
        // The borrowing word iterator: zero heap allocations per word
        // (the router's length feature and admission estimates hit this
        // on every request).
        let mut i = 0;
        let m = measure("tokenizer word_count (borrowing iter)", 200_000, || {
            let _ = tokenizer::word_count(&prompts[i % prompts.len()]);
            i += 1;
        });
        println!("{}", m.report());
    }

    if selected("selection") {
        let mut registry = Registry::new(&zoo(), 300.0);
        for s in &mut registry.services {
            s.ready_replicas = 1;
        }
        let w = Weights::from_profile(&Profile::BALANCED);
        let class = Classification {
            complexity: 1,
            confidence: 0.9,
            mode: RouterMode::Hybrid,
            overhead_s: 0.0,
        };
        let m = measure("matrix selection (Alg. 2, 12 cells)", 500_000, || {
            let _ = select(&registry, w, &class, 50.0, 80.0, |_| 0.0);
        });
        println!("{}", m.report());
        assert!(m.per_iter_us() < 10.0, "selection too slow");
    }

    if selected("sim") {
        let lib = lib.as_ref().expect("sim section needs the template library");
        let sc = routed(20_000, RouterMode::Keyword, SelectionPolicy::MultiObjective);
        let t0 = std::time::Instant::now();
        let rep = simulate(lib, &sc);
        let dt = t0.elapsed().as_secs_f64();
        // Each request ≈ 4 events (arrival, start, finish, control share).
        println!(
            "{:<44} {:>10} reqs   {:>12.0} req/s     ({:.2}s wall)",
            "simulator end-to-end", rep.records.len(),
            rep.records.len() as f64 / dt, dt
        );
    }

    if selected("kv") {
        use pick_and_spin::backend::kv_cache::{
            KvBlockManager, PrefixCacheConfig, SeqId,
        };
        let m = measure("kv admit+release (reservation)", 500_000, || {
            let mut kv = KvBlockManager::new(64, 16);
            kv.admit(SeqId(1), 40, 24).unwrap();
            kv.release(SeqId(1));
        });
        println!("{}", m.report());
        // Radix-hit path: after the first admission every walk matches
        // the cached 4-block chain.
        let mut kv =
            KvBlockManager::with_prefix_cache(64, 16, PrefixCacheConfig::default());
        let ids: Vec<i32> = (0..64).collect();
        let mut n = 0u64;
        let m = measure("kv admit+release (radix prefix hit)", 500_000, || {
            kv.admit_prefix(SeqId(n), &ids, 8).unwrap();
            kv.release(SeqId(n));
            n += 1;
        });
        println!("{}", m.report());
    }

    if selected("pool") {
        // Continuous batching vs the serial seed path, on the calibrated
        // synthetic engine (same per-step cost shape as the PJRT CPU
        // plugin: dispatch-dominated, so batching amortizes dispatch).
        use pick_and_spin::backend::batcher::BatchPolicy;
        use pick_and_spin::backend::kv_cache::PrefixCacheConfig;
        use pick_and_spin::backend::scheduler::{
            Admit, Scheduler, SchedulerConfig, SimStepEngine,
        };

        let serve = |max_inflight: usize, max_batch: usize| -> (usize, f64) {
            let mut sched: Scheduler<SimStepEngine, usize> = Scheduler::new(
                SimStepEngine::calibrated(),
                SchedulerConfig {
                    policy: BatchPolicy::custom(max_batch, 1, 0.001),
                    max_inflight,
                    kv_blocks: 1024,
                    kv_block_tokens: 16,
                    // Short distinct prompts — the cache is inert here;
                    // the production default keeps the comparison honest.
                    prefix_cache: PrefixCacheConfig::default(),
                    speculative: pick_and_spin::config::SpeculativeConfig::disabled(),
                },
            );
            let mut queued: Vec<usize> = (0..64).rev().collect();
            let t0 = std::time::Instant::now();
            let mut tokens = 0usize;
            let mut done = 0usize;
            while done < 64 {
                while let Some(i) = queued.pop() {
                    match sched.admit(&format!("bench prompt number {i}"), 16, 5, i) {
                        Admit::Admitted => {}
                        Admit::Rejected(i) => {
                            queued.push(i);
                            break;
                        }
                        Admit::Failed(_, e) => panic!("sim engine failed: {e}"),
                    }
                }
                let t = sched.tick(t0.elapsed().as_secs_f64()).unwrap();
                done += t.finished.len();
                tokens += t.finished.iter().map(|f| f.tokens.len()).sum::<usize>();
            }
            (tokens, t0.elapsed().as_secs_f64())
        };

        let (serial_toks, serial_s) = serve(1, 1); // the seed's serial path
        let (pool_toks, pool_s) = serve(16, 8); // the engine-pool path
        let serial_tps = serial_toks as f64 / serial_s;
        let pool_tps = pool_toks as f64 / pool_s;
        println!(
            "{:<44} {:>10} toks   {:>12.0} tok/s     (serial, batch 1)",
            "scheduler throughput (sim engine)", serial_toks, serial_tps
        );
        println!(
            "{:<44} {:>10} toks   {:>12.0} tok/s     (16 slots, batch ≤8, {:.2}× serial)",
            "scheduler throughput (sim engine)", pool_toks, pool_tps,
            pool_tps / serial_tps
        );
        assert!(
            pool_tps > serial_tps,
            "continuous batching must beat the serial path \
             ({pool_tps:.0} vs {serial_tps:.0} tok/s)"
        );
    }

    if selected("prefix") {
        // Shared-prefix workload: 64 requests carrying one 48-word
        // system preamble plus a short per-request question — the shape
        // of the paper's 31k-prompt benchmark suites. With the radix
        // prefix cache the first request seeds the preamble's blocks and
        // every later prefill pays only its suffix.
        use pick_and_spin::backend::batcher::BatchPolicy;
        use pick_and_spin::backend::kv_cache::PrefixCacheConfig;
        use pick_and_spin::backend::scheduler::{
            Admit, Scheduler, SchedulerConfig, SimStepEngine,
        };

        let preamble = vec!["shared"; 48].join(" ");
        let prompts: Vec<String> = (0..64)
            .map(|i| format!("{preamble} question number {i} please"))
            .collect();
        let serve = |prefix: PrefixCacheConfig| -> (usize, f64, u64) {
            let mut sched: Scheduler<SimStepEngine, usize> = Scheduler::new(
                SimStepEngine::calibrated(),
                SchedulerConfig {
                    policy: BatchPolicy::custom(8, 4, 0.001),
                    max_inflight: 16,
                    kv_blocks: 1024,
                    kv_block_tokens: 16,
                    prefix_cache: prefix,
                    speculative: pick_and_spin::config::SpeculativeConfig::disabled(),
                },
            );
            let mut queued: Vec<usize> = (0..prompts.len()).rev().collect();
            let t0 = std::time::Instant::now();
            let mut tokens = 0usize;
            let mut done = 0usize;
            while done < prompts.len() {
                while let Some(i) = queued.pop() {
                    match sched.admit(&prompts[i], 8, 53, i) {
                        Admit::Admitted => {}
                        Admit::Rejected(i) => {
                            queued.push(i);
                            break;
                        }
                        Admit::Failed(_, e) => panic!("sim engine failed: {e}"),
                    }
                }
                let t = sched.tick(t0.elapsed().as_secs_f64()).unwrap();
                done += t.finished.len();
                tokens += t.finished.iter().map(|f| f.tokens.len()).sum::<usize>();
            }
            (tokens, t0.elapsed().as_secs_f64(), sched.prefix_stats().hit_tokens)
        };

        let (cold_toks, cold_s, _) = serve(PrefixCacheConfig::disabled());
        let (warm_toks, warm_s, warm_hits) = serve(PrefixCacheConfig::default());
        let cold_tps = cold_toks as f64 / cold_s;
        let warm_tps = warm_toks as f64 / warm_s;
        println!(
            "{:<44} {:>10} toks   {:>12.0} tok/s     (no cache)",
            "shared-prefix prefill (sim engine)", cold_toks, cold_tps
        );
        println!(
            "{:<44} {:>10} toks   {:>12.0} tok/s     (radix cache, {} hit toks, {:.2}× no-cache)",
            "shared-prefix prefill (sim engine)", warm_toks, warm_tps,
            warm_hits, warm_tps / cold_tps
        );
        assert!(warm_hits > 0, "shared-prefix workload must hit the cache");
        assert!(
            warm_tps > cold_tps,
            "prefix caching must beat full prefill on a shared-prefix \
             workload ({warm_tps:.0} vs {cold_tps:.0} tok/s)"
        );
    }

    if selected("affinity") {
        // Fleet-level shared-prefix serving through the full gateway:
        // several prompt families, each carrying a 64-word preamble (4
        // KV blocks), served by 1 replica, by 3 replicas with blind
        // tier-queue fan-out, and by 3 replicas with cache-affinity
        // routing. The acceptance signal: affinity keeps the aggregate
        // prefix hit-token rate from degrading as replicas grow —
        // at least the blind fan-out rate, and within a sliver of the
        // single-replica (perfect-locality) rate.
        use pick_and_spin::config::Config;
        use pick_and_spin::gateway::LiveStack;
        use std::sync::atomic::Ordering;

        let families = 8usize;
        let rounds = 15usize;
        let preambles: Vec<String> = (0..families)
            .map(|f| vec![format!("family{f}"); 64].join(" "))
            .collect();
        let run = |replicas: usize, affinity: bool| -> (f64, u64, u64) {
            let mut cfg = Config::default();
            cfg.pool.replicas = [replicas, 1, 1];
            cfg.pool.max_inflight = 8;
            cfg.pool.flush_timeout_s = 0.001;
            cfg.pool.affinity.enabled = affinity;
            let stack = LiveStack::start_sim(&cfg).expect("bench stack");
            for r in 0..rounds {
                for (f, pre) in preambles.iter().enumerate() {
                    stack
                        .complete(&format!("{pre} what is {f} plus {r}?"), 4)
                        .expect("bench request");
                }
            }
            // Replica loops flush scheduler stats on their next turn.
            std::thread::sleep(std::time::Duration::from_millis(20));
            let m = &stack.metrics;
            let hits = m.prefix_hit_tokens.load(Ordering::Relaxed);
            let miss = m.prefix_miss_tokens.load(Ordering::Relaxed);
            let rate = hits as f64 / (hits + miss).max(1) as f64;
            (rate, hits, m.affinity_hits.load(Ordering::Relaxed))
        };

        let (single_rate, single_hits, _) = run(1, false);
        let (blind_rate, blind_hits, _) = run(3, false);
        let (aff_rate, aff_hits_toks, aff_hits) = run(3, true);
        println!(
            "{:<44} {:>10} toks   {:>11.1}% hit rate  (1 replica)",
            "fleet shared-prefix hit tokens", single_hits, single_rate * 100.0
        );
        println!(
            "{:<44} {:>10} toks   {:>11.1}% hit rate  (3 replicas, blind fan-out)",
            "fleet shared-prefix hit tokens", blind_hits, blind_rate * 100.0
        );
        println!(
            "{:<44} {:>10} toks   {:>11.1}% hit rate  (3 replicas, affinity, {aff_hits} routed hits)",
            "fleet shared-prefix hit tokens", aff_hits_toks, aff_rate * 100.0
        );
        assert!(aff_hits > 0, "affinity routing never placed a request");
        assert!(
            aff_rate >= blind_rate,
            "affinity must not hit less than blind fan-out \
             ({:.1}% vs {:.1}%)",
            aff_rate * 100.0,
            blind_rate * 100.0
        );
        assert!(
            aff_rate >= 0.95 * single_rate,
            "3-replica affinity must stay within 5% of single-replica \
             locality ({:.1}% vs {:.1}%)",
            aff_rate * 100.0,
            single_rate * 100.0
        );
    }

    if selected("speculative") {
        // Cross-tier speculative decoding end-to-end: the pinned BENCH_7
        // scenario — 64 concurrent hard prompts (routed to verify tiers),
        // 32-token budgets, draft window 4 — served plain, speculative at
        // a fixed 0.7 sim acceptance, and speculative at acceptance 0
        // (every draft rejected; the EMA latch must make it ≈ plain).
        // Tokens/sec takes the best of 3 repeats per scenario to damp
        // shared-runner noise; TTFT/TPOT percentiles pool all repeats.
        use pick_and_spin::config::Config;
        use pick_and_spin::gateway::LiveStack;
        use pick_and_spin::util::json::Json;
        use pick_and_spin::util::stats::percentile;
        use std::sync::atomic::Ordering;
        use std::sync::Arc;

        const REQS: usize = 64;
        const MAX_NEW: usize = 32;
        const DRAFT_TOKENS: usize = 4;
        const REPEATS: usize = 3;

        struct SpecRun {
            tps: f64,
            ttfts: Vec<f64>,
            tpots: Vec<f64>,
            drafted: u64,
            accepted: u64,
            rejected: u64,
            verify_steps: u64,
        }

        let run = |enabled: bool, accept: f64| -> SpecRun {
            let mut out = SpecRun {
                tps: 0.0,
                ttfts: Vec::new(),
                tpots: Vec::new(),
                drafted: 0,
                accepted: 0,
                rejected: 0,
                verify_steps: 0,
            };
            for _ in 0..REPEATS {
                let mut cfg = Config::default();
                cfg.pool.replicas = [1, 1, 1];
                cfg.pool.max_inflight = 16;
                cfg.pool.max_decode_batch = 8;
                cfg.pool.flush_timeout_s = 0.001;
                cfg.pool.scale_interval_s = 0.02;
                cfg.pool.speculative.enabled = enabled;
                cfg.pool.speculative.draft_tier = 0;
                cfg.pool.speculative.draft_tokens = DRAFT_TOKENS;
                cfg.pool.speculative.sim_accept = accept;
                let stack = Arc::new(LiveStack::start_sim(&cfg).expect("bench stack"));
                // Let the router publish draft-tier availability (first
                // control pass) before the burst arrives.
                std::thread::sleep(std::time::Duration::from_millis(120));
                let t0 = std::time::Instant::now();
                let handles: Vec<_> = (0..REQS)
                    .map(|i| {
                        let s = Arc::clone(&stack);
                        std::thread::spawn(move || {
                            s.complete(
                                &format!(
                                    "prove that series {i} converges and \
                                     derive the bound"
                                ),
                                MAX_NEW,
                            )
                            .expect("bench request")
                        })
                    })
                    .collect();
                let mut toks = 0usize;
                for h in handles {
                    let r = h.join().expect("bench thread");
                    toks += r.tokens.len();
                    out.ttfts.push(r.ttft_s);
                    if r.tokens.len() > 1 {
                        out.tpots.push(
                            (r.latency_s - r.ttft_s) / (r.tokens.len() - 1) as f64,
                        );
                    }
                }
                out.tps = out.tps.max(toks as f64 / t0.elapsed().as_secs_f64());
                // Replica loops flush scheduler stats on their next turn.
                std::thread::sleep(std::time::Duration::from_millis(20));
                let m = &stack.metrics;
                out.drafted = m.spec_drafted_tokens.load(Ordering::Relaxed);
                out.accepted = m.spec_accepted_tokens.load(Ordering::Relaxed);
                out.rejected = m.spec_rejected_tokens.load(Ordering::Relaxed);
                out.verify_steps = m.spec_verify_steps.load(Ordering::Relaxed);
            }
            out
        };

        let plain = run(false, 0.0);
        let spec = run(true, 0.7);
        let zero = run(true, 0.0);
        let line = |name: &str, r: &SpecRun, note: &str| {
            println!(
                "{:<44} {:>12.0} tok/s   ttft p50 {:>6.2} ms   tpot p50 {:>7.1} µs   ({note})",
                name,
                r.tps,
                percentile(&r.ttfts, 50.0) * 1e3,
                percentile(&r.tpots, 50.0) * 1e6,
            );
        };
        line("speculative decode (gateway, sim)", &plain, "plain");
        line("speculative decode (gateway, sim)", &spec, "accept 0.7, k=4");
        line("speculative decode (gateway, sim)", &zero, "accept 0.0, k=4");
        assert!(
            spec.drafted > 0 && spec.accepted > 0,
            "speculation never engaged (drafted {}, accepted {})",
            spec.drafted,
            spec.accepted
        );
        assert!(
            spec.tps > plain.tps,
            "speculative decode at 0.7 acceptance must beat plain \
             ({:.0} vs {:.0} tok/s)",
            spec.tps,
            plain.tps
        );
        assert!(
            zero.tps >= 0.95 * plain.tps,
            "speculation at 0 acceptance must auto-disable to within 5% \
             of plain ({:.0} vs {:.0} tok/s)",
            zero.tps,
            plain.tps
        );

        let block = |r: &SpecRun| {
            Json::obj(vec![
                ("tok_s", Json::num(r.tps)),
                ("ttft_p50_s", Json::num(percentile(&r.ttfts, 50.0))),
                ("ttft_p95_s", Json::num(percentile(&r.ttfts, 95.0))),
                ("tpot_p50_s", Json::num(percentile(&r.tpots, 50.0))),
                ("tpot_p95_s", Json::num(percentile(&r.tpots, 95.0))),
                ("spec_drafted_tokens", Json::num(r.drafted as f64)),
                ("spec_accepted_tokens", Json::num(r.accepted as f64)),
                ("spec_rejected_tokens", Json::num(r.rejected as f64)),
                ("spec_verify_steps", Json::num(r.verify_steps as f64)),
            ])
        };
        let report = Json::obj(vec![
            ("bench", Json::str("speculative")),
            (
                "scenario",
                Json::obj(vec![
                    ("requests", Json::num(REQS as f64)),
                    ("max_tokens", Json::num(MAX_NEW as f64)),
                    ("draft_tokens", Json::num(DRAFT_TOKENS as f64)),
                    ("repeats", Json::num(REPEATS as f64)),
                ]),
            ),
            ("plain", block(&plain)),
            ("spec_accept_70", block(&spec)),
            ("spec_accept_0", block(&zero)),
            ("speedup_at_70", Json::num(spec.tps / plain.tps)),
        ]);
        std::fs::write("BENCH_7.json", report.dump()).expect("write BENCH_7.json");
        println!("wrote BENCH_7.json (speedup at 0.7 acceptance: {:.2}x)", spec.tps / plain.tps);
    }

    if selected("overload") {
        // Overload control end-to-end: the pinned BENCH_8 scenario — a
        // batch flood at ~2× the single-slot drain rate of one tier,
        // followed by a burst of deadline-carrying interactive requests.
        // Served with admission off (legacy FIFO: interactive starves
        // behind the flood and times out) and on (priority admission +
        // watermark shedding: batch is shed, interactive overtakes the
        // flood and makes its deadline). The service time is calibrated
        // first so the deadline scales with the machine instead of being
        // a magic number.
        use pick_and_spin::config::{Config, Priority};
        use pick_and_spin::gateway::{
            CompletionError, CompletionRequest, FailureKind, LiveStack,
        };
        use pick_and_spin::util::json::Json;
        use std::sync::atomic::Ordering;
        use std::sync::Arc;

        const BATCH: usize = 96;
        const INTERACTIVE: usize = 16;
        const BATCH_TOKENS: usize = 48;
        const INTER_TOKENS: usize = 8;

        let mk_cfg = |admission: bool| {
            let mut cfg = Config::default();
            cfg.pool.replicas = [1, 1, 1]; // plan_tier's ceiling: no scale-out
            cfg.pool.max_inflight = 1;
            cfg.pool.flush_timeout_s = 0.001;
            cfg.pool.scale_interval_s = 0.02;
            cfg.pool.queue_capacity = 256;
            cfg.pool.admission.enabled = admission;
            cfg.pool.admission.watermark = 0.125; // shed past 32 queued
            cfg
        };
        let hard = |i: usize| {
            format!("prove that series {i} converges and derive the bound")
        };

        // Calibrate the single-slot service time (large tier, serial).
        let per_job_s = {
            let stack = LiveStack::start_sim(&mk_cfg(false)).expect("bench stack");
            std::thread::sleep(std::time::Duration::from_millis(120));
            let t0 = std::time::Instant::now();
            for i in 0..8 {
                stack.complete(&hard(i), BATCH_TOKENS).expect("calibration");
            }
            t0.elapsed().as_secs_f64() / 8.0
        };
        let deadline_s = (per_job_s * 24.0).clamp(0.05, 10.0);

        struct OverloadRun {
            inter_ok: usize,
            batch_ok: usize,
            batch_shed: usize,
            shed: [u64; 3],
            rejected_backlog: u64,
            rejected_deadline: u64,
            wall_s: f64,
        }

        let run = |admission: bool| -> OverloadRun {
            let stack = Arc::new(LiveStack::start_sim(&mk_cfg(admission)).expect("bench stack"));
            std::thread::sleep(std::time::Duration::from_millis(120));
            let t0 = std::time::Instant::now();
            let batch: Vec<_> = (0..BATCH)
                .map(|i| {
                    let s = Arc::clone(&stack);
                    std::thread::spawn(move || {
                        s.complete_request(
                            CompletionRequest::new(hard(i))
                                .max_tokens(BATCH_TOKENS)
                                .priority(Priority::Batch),
                        )
                    })
                })
                .collect();
            // Let the flood buffer (and a drain sample land) before the
            // interactive burst arrives behind it.
            std::thread::sleep(std::time::Duration::from_secs_f64(
                (per_job_s * 8.0).max(0.05),
            ));
            let inter: Vec<_> = (0..INTERACTIVE)
                .map(|i| {
                    let s = Arc::clone(&stack);
                    std::thread::spawn(move || {
                        s.complete_request(
                            CompletionRequest::new(hard(1000 + i))
                                .max_tokens(INTER_TOKENS)
                                .priority(Priority::Interactive)
                                .deadline_s(deadline_s),
                        )
                    })
                })
                .collect();
            // An Ok under a deadline IS the goodput signal: the caller
            // wait is bounded by the deadline, so every completion met it.
            let inter_ok = inter
                .into_iter()
                .map(|h| h.join().expect("bench thread"))
                .filter(|r| r.is_ok())
                .count();
            let mut batch_ok = 0usize;
            let mut batch_shed = 0usize;
            for h in batch {
                match h.join().expect("bench thread") {
                    Ok(_) => batch_ok += 1,
                    Err(e) => {
                        let shed = e
                            .downcast_ref::<CompletionError>()
                            .map(|ce| {
                                matches!(
                                    ce.kind,
                                    FailureKind::Shed | FailureKind::QueueFull
                                )
                            })
                            .unwrap_or(false);
                        assert!(shed, "batch request failed untyped: {e}");
                        batch_shed += 1;
                    }
                }
            }
            let m = &stack.metrics;
            OverloadRun {
                inter_ok,
                batch_ok,
                batch_shed,
                shed: std::array::from_fn(|p| {
                    m.shed_total[p].iter().map(|c| c.load(Ordering::Relaxed)).sum()
                }),
                rejected_backlog: m.admission_rejected_backlog.load(Ordering::Relaxed),
                rejected_deadline: m.admission_rejected_deadline.load(Ordering::Relaxed),
                wall_s: t0.elapsed().as_secs_f64(),
            }
        };

        let off = run(false);
        let on = run(true);
        let line = |name: &str, r: &OverloadRun, note: &str| {
            println!(
                "{:<44} {:>3}/{} interactive in deadline   {:>3}/{} batch ok, {} shed   ({:.2}s wall, {note})",
                name, r.inter_ok, INTERACTIVE, r.batch_ok, BATCH, r.batch_shed, r.wall_s
            );
        };
        line("overload 2x (gateway, sim)", &off, "admission off");
        line("overload 2x (gateway, sim)", &on, "admission on");
        assert!(
            on.inter_ok > off.inter_ok,
            "admission control must lift interactive goodput under 2x \
             overload ({} vs {} of {INTERACTIVE} in deadline)",
            on.inter_ok,
            off.inter_ok
        );
        assert!(
            on.shed[2] > 0,
            "the 2x batch flood must trip the watermark shed"
        );
        assert_eq!(
            (on.shed[0], on.shed[1]),
            (0, 0),
            "only batch priority may be shed under the 2x flood"
        );
        assert_eq!(
            on.batch_ok + on.batch_shed,
            BATCH,
            "every batch request must resolve exactly once"
        );

        let block = |r: &OverloadRun| {
            Json::obj(vec![
                ("interactive_in_deadline", Json::num(r.inter_ok as f64)),
                ("batch_completed", Json::num(r.batch_ok as f64)),
                ("batch_shed", Json::num(r.batch_shed as f64)),
                ("shed_interactive", Json::num(r.shed[0] as f64)),
                ("shed_standard", Json::num(r.shed[1] as f64)),
                ("shed_batch", Json::num(r.shed[2] as f64)),
                ("rejected_backlog", Json::num(r.rejected_backlog as f64)),
                ("rejected_deadline", Json::num(r.rejected_deadline as f64)),
                ("wall_s", Json::num(r.wall_s)),
            ])
        };
        let report = Json::obj(vec![
            ("bench", Json::str("overload")),
            (
                "scenario",
                Json::obj(vec![
                    ("batch_requests", Json::num(BATCH as f64)),
                    ("interactive_requests", Json::num(INTERACTIVE as f64)),
                    ("batch_tokens", Json::num(BATCH_TOKENS as f64)),
                    ("interactive_tokens", Json::num(INTER_TOKENS as f64)),
                    ("per_job_s", Json::num(per_job_s)),
                    ("deadline_s", Json::num(deadline_s)),
                ]),
            ),
            ("admission_off", block(&off)),
            ("admission_on", block(&on)),
        ]);
        std::fs::write("BENCH_8.json", report.dump()).expect("write BENCH_8.json");
        println!(
            "wrote BENCH_8.json (interactive goodput {} -> {} of {INTERACTIVE})",
            off.inter_ok, on.inter_ok
        );
    }

    if selected("trace") {
        // Tracing overhead end-to-end: the pinned BENCH_9 scenario — 64
        // concurrent prompts, 24-token budgets — served with tracing off
        // (the default null-pointer path) and on (every request sampled,
        // full span timelines through the flight recorder). The
        // acceptance gate: tracing-on throughput within 5% of off.
        // Tokens/sec takes the best of 3 repeats per scenario to damp
        // shared-runner noise; the recorder's spans give the TTFT phase
        // decomposition (admit/queued/prefill) the JSON reports.
        use pick_and_spin::config::Config;
        use pick_and_spin::gateway::LiveStack;
        use pick_and_spin::telemetry::trace::SpanKind;
        use pick_and_spin::util::json::Json;
        use pick_and_spin::util::stats::percentile;
        use std::sync::Arc;

        const REQS: usize = 64;
        const MAX_NEW: usize = 24;
        const REPEATS: usize = 3;

        struct TraceRun {
            tps: f64,
            ttfts: Vec<f64>,
            // Mean seconds and sample count per span kind, from the
            // last repeat's flight recorder.
            phase_mean_s: Vec<(&'static str, f64, usize)>,
            traces: usize,
        }

        let run = |enabled: bool| -> TraceRun {
            let mut out = TraceRun {
                tps: 0.0,
                ttfts: Vec::new(),
                phase_mean_s: Vec::new(),
                traces: 0,
            };
            for _ in 0..REPEATS {
                let mut cfg = Config::default();
                cfg.pool.replicas = [1, 1, 1];
                cfg.pool.max_inflight = 16;
                cfg.pool.max_decode_batch = 8;
                cfg.pool.flush_timeout_s = 0.001;
                cfg.pool.scale_interval_s = 0.02;
                cfg.pool.trace.enabled = enabled;
                cfg.pool.trace.sample_rate = 1.0;
                cfg.pool.trace.ring_size = REQS * 2;
                let stack = Arc::new(LiveStack::start_sim(&cfg).expect("bench stack"));
                std::thread::sleep(std::time::Duration::from_millis(120));
                let t0 = std::time::Instant::now();
                let handles: Vec<_> = (0..REQS)
                    .map(|i| {
                        let s = Arc::clone(&stack);
                        std::thread::spawn(move || {
                            s.complete(&format!("what is {i} plus {i}?"), MAX_NEW)
                                .expect("bench request")
                        })
                    })
                    .collect();
                let mut toks = 0usize;
                for h in handles {
                    let r = h.join().expect("bench thread");
                    toks += r.tokens.len();
                    out.ttfts.push(r.ttft_s);
                }
                out.tps = out.tps.max(toks as f64 / t0.elapsed().as_secs_f64());
                // The scheduler records a trace after replying; give the
                // last few a beat to land in the ring.
                std::thread::sleep(std::time::Duration::from_millis(20));
                let records = stack.metrics.recorder.snapshot();
                out.traces = records.len();
                out.phase_mean_s = [
                    SpanKind::Admit,
                    SpanKind::Queued,
                    SpanKind::Prefill,
                    SpanKind::Decode,
                ]
                .iter()
                .map(|kind| {
                    let durs: Vec<f64> = records
                        .iter()
                        .flat_map(|r| r.spans.iter())
                        .filter(|s| s.kind == *kind)
                        .map(|s| s.dur_s())
                        .collect();
                    let mean = if durs.is_empty() {
                        0.0
                    } else {
                        durs.iter().sum::<f64>() / durs.len() as f64
                    };
                    (kind.name(), mean, durs.len())
                })
                .collect();
            }
            out
        };

        let off = run(false);
        let on = run(true);
        let line = |name: &str, r: &TraceRun, note: &str| {
            println!(
                "{:<44} {:>12.0} tok/s   ttft p50 {:>6.2} ms   ({} traces, {note})",
                name,
                r.tps,
                percentile(&r.ttfts, 50.0) * 1e3,
                r.traces,
            );
        };
        line("request tracing (gateway, sim)", &off, "tracing off");
        line("request tracing (gateway, sim)", &on, "tracing on, sample 1.0");
        assert_eq!(off.traces, 0, "tracing off must record nothing");
        assert!(
            on.traces >= REQS,
            "tracing on must record every request ({} of {REQS})",
            on.traces
        );
        assert!(
            on.tps >= 0.95 * off.tps,
            "tracing must cost under 5% throughput \
             ({:.0} vs {:.0} tok/s)",
            on.tps,
            off.tps
        );

        let phases = Json::obj(
            on.phase_mean_s
                .iter()
                .map(|(name, mean, n)| {
                    (
                        *name,
                        Json::obj(vec![
                            ("mean_s", Json::num(*mean)),
                            ("spans", Json::num(*n as f64)),
                        ]),
                    )
                })
                .collect(),
        );
        let block = |r: &TraceRun| {
            Json::obj(vec![
                ("tok_s", Json::num(r.tps)),
                ("ttft_p50_s", Json::num(percentile(&r.ttfts, 50.0))),
                ("ttft_p95_s", Json::num(percentile(&r.ttfts, 95.0))),
                ("traces_recorded", Json::num(r.traces as f64)),
            ])
        };
        let report = Json::obj(vec![
            ("bench", Json::str("trace")),
            (
                "scenario",
                Json::obj(vec![
                    ("requests", Json::num(REQS as f64)),
                    ("max_tokens", Json::num(MAX_NEW as f64)),
                    ("repeats", Json::num(REPEATS as f64)),
                ]),
            ),
            ("tracing_off", block(&off)),
            ("tracing_on", block(&on)),
            ("ttft_phase_decomposition", phases),
            ("overhead_ratio", Json::num(on.tps / off.tps.max(1e-9))),
        ]);
        std::fs::write("BENCH_9.json", report.dump()).expect("write BENCH_9.json");
        println!(
            "wrote BENCH_9.json (tracing-on throughput {:.1}% of off)",
            100.0 * on.tps / off.tps.max(1e-9)
        );
    }

    if selected("routing") {
        // Learned routing end-to-end: the pinned BENCH_10 scenario — the
        // mixed 8-benchmark workload at 8 QPS on the 64-GPU simulated
        // cluster, served once with the static TierDirected policy
        // (every class-2 prompt pinned to the large tier: high success,
        // very expensive) and once with the contextual bandit learning
        // on top of the same fleet. The acceptance gate: the learner
        // must lower summed request cost per successful answer without
        // collapsing the success rate.
        use pick_and_spin::sim::SimReport;
        use pick_and_spin::util::json::Json;

        // Runs on the built-in synthetic library when `make artifacts`
        // hasn't happened (the CI case), or the real one when it has.
        let lib = library_or_synthetic();
        let mut sc = base_config(3_000);
        sc.rate_qps = 8.0;
        sc.policy = SelectionPolicy::TierDirected;
        let stat = simulate(&lib, &sc);
        sc.pool.routing.bandit.enabled = true;
        let learned = simulate(&lib, &sc);

        let line = |name: &str, r: &SimReport, note: &str| {
            println!(
                "{:<44} {:>9.4} $/success   {:>5.1}% success   {:>6.2}s mean lat   ({note})",
                name,
                r.cost_per_success_usd(),
                r.success_rate() * 100.0,
                r.mean_latency_s(),
            );
        };
        line("learned routing (sim, mixed workload)", &stat, "static tier-directed");
        line("learned routing (sim, mixed workload)", &learned, "contextual bandit");
        assert!(
            !learned.bandit_arms.is_empty(),
            "the learner never received feedback"
        );
        assert!(
            learned.cost_per_success_usd() < stat.cost_per_success_usd(),
            "the bandit must lower cost per success \
             ({:.4} vs {:.4} $/success)",
            learned.cost_per_success_usd(),
            stat.cost_per_success_usd()
        );
        assert!(
            learned.success_rate() > 0.4,
            "learned routing must still answer ({:.1}% success)",
            learned.success_rate() * 100.0
        );

        let block = |r: &SimReport| {
            Json::obj(vec![
                ("cost_per_success_usd", Json::num(r.cost_per_success_usd())),
                ("success_rate", Json::num(r.success_rate())),
                ("mean_latency_s", Json::num(r.mean_latency_s())),
                ("requests", Json::num(r.records.len() as f64)),
            ])
        };
        let arms = Json::arr(learned.bandit_arms.iter().map(|a| {
            Json::obj(vec![
                ("class", Json::num(a.class as f64)),
                ("tier", Json::num(a.tier as f64)),
                ("selections", Json::num(a.selections as f64)),
                ("successes", Json::num(a.successes as f64)),
                ("failures", Json::num(a.failures as f64)),
                ("mean_reward", Json::num(a.mean_reward)),
                ("mean_latency_s", Json::num(a.mean_latency_s)),
                ("mean_cost_usd", Json::num(a.mean_cost_usd)),
            ])
        }));
        let report = Json::obj(vec![
            ("bench", Json::str("routing")),
            (
                "scenario",
                Json::obj(vec![
                    ("requests", Json::num(sc.n_requests as f64)),
                    ("rate_qps", Json::num(sc.rate_qps)),
                    ("seed", Json::num(sc.seed as f64)),
                ]),
            ),
            ("static_tier_directed", block(&stat)),
            ("bandit", block(&learned)),
            ("bandit_arms", arms),
            (
                "cost_per_success_ratio",
                Json::num(
                    learned.cost_per_success_usd()
                        / stat.cost_per_success_usd().max(1e-12),
                ),
            ),
        ]);
        std::fs::write("BENCH_10.json", report.dump()).expect("write BENCH_10.json");
        println!(
            "wrote BENCH_10.json (cost/success {:.4} -> {:.4} $)",
            stat.cost_per_success_usd(),
            learned.cost_per_success_usd()
        );
    }

    // Live PJRT path (needs artifacts).
    let artifacts = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if std::path::Path::new(&format!("{artifacts}/manifest.json")).exists() {
        use pick_and_spin::router::Classifier;
        use pick_and_spin::runtime::Runtime;
        let mut rt = Runtime::load(artifacts).expect("runtime");

        if selected("classifier") {
            let mut cls = rt.classifier_engine().expect("classifier");
            let mut i = 0;
            let m = measure("live semantic classify (PJRT)", 2_000, || {
                let _ = cls.probs(&prompts[i % prompts.len()]).unwrap();
                i += 1;
            });
            println!("{}", m.report());
            assert!(m.per_iter_us() < 5_000.0, "semantic classify too slow");
        }

        if selected("decode") {
            for tier in ["small", "medium", "large"] {
                let lm = rt.lm_engine(tier, &[1, 4, 8]).expect("engine");
                lm.generate("warm up the engine", 4).unwrap();
                let m = measure(&format!("live decode step b=1 ({tier})"), 64, || {
                    let _ = lm.generate("a prompt of medium length for decoding", 8);
                });
                // The measured closure runs prefill + 7 decode steps.
                println!(
                    "{}   (≈{:.2} ms/token)",
                    m.report(),
                    m.per_iter_us() / 8.0 / 1000.0
                );
                // Batched throughput:
                let p: Vec<&str> = (0..8).map(|_| "a medium length batch prompt").collect();
                let t0 = std::time::Instant::now();
                let gens = lm.generate_batch(&p, 8).unwrap();
                let dt = t0.elapsed().as_secs_f64();
                let toks: usize = gens.iter().map(|g| g.tokens.len()).sum();
                println!(
                    "{:<44} {:>10} toks   {:>12.0} tok/s     (batch 8, {tier})",
                    "live batched decode (PJRT)", toks, toks as f64 / dt
                );
            }
        }
    } else {
        println!("(live PJRT benches skipped: artifacts not built)");
    }

    println!("\ndone.");
}
