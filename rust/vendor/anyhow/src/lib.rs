//! A minimal, API-compatible shim of the `anyhow` crate.
//!
//! The repository builds in offline/hermetic environments with no
//! crates.io access, so the subset of `anyhow` the codebase uses is
//! vendored here as a path dependency: [`Error`], [`Result`], the
//! [`anyhow!`]/[`bail!`]/[`ensure!`] macros, and the [`Context`]
//! extension trait. Errors are a flat context chain of strings — enough
//! for `{e}`, `{e:#}` and `{e:?}` reporting — not a full dyn-Error tree.
//!
//! Like the real crate, [`Error`] deliberately does **not** implement
//! `std::error::Error`: that keeps the blanket `From<E: std::error::Error>`
//! conversion (what makes `?` work) coherent.

use std::fmt;

/// `Result<T, anyhow::Error>` alias, matching the real crate.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A string-chain error: the outermost context first, root cause last.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a printable message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context layer (used by [`Context`]).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    /// `{e}` prints the outermost message; `{e:#}` the whole chain.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    /// `{e:?}` mirrors anyhow's report shape: message plus caused-by list.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

/// `?` conversion from any std error. Coherent with `From<T> for T`
/// because `Error` itself does not implement `std::error::Error`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // Preserve the std source chain as context layers.
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

mod private {
    /// Sealed unification of "things that convert into [`crate::Error`]":
    /// std errors and `Error` itself. The two impls are coherent because
    /// `Error` never implements `std::error::Error`.
    pub trait IntoError {
        fn into_error(self) -> crate::Error;
    }

    impl<E: std::error::Error + Send + Sync + 'static> IntoError for E {
        fn into_error(self) -> crate::Error {
            crate::Error::from(self)
        }
    }

    impl IntoError for crate::Error {
        fn into_error(self) -> crate::Error {
            self
        }
    }
}

/// `.context(..)` / `.with_context(..)` on fallible results.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: private::IntoError> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into_error().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into_error().context(f()))
    }
}

/// Construct an [`Error`] from a format string, a printable value, or
/// format args — the real crate's three arms.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Early-return with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Assert a condition, early-returning an [`Error`] when it fails.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        Err(anyhow!("root {}", 42))
    }

    #[test]
    fn display_and_alternate() {
        let e = fails().context("outer").unwrap_err();
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: root 42");
        assert_eq!(e.root_cause(), "root 42");
    }

    #[test]
    fn macro_accepts_all_three_arg_shapes() {
        let x = 7;
        assert_eq!(format!("{}", anyhow!("literal {x}")), "literal 7");
        let owned = String::from("from a value");
        assert_eq!(format!("{}", anyhow!(owned)), "from a value");
        assert_eq!(format!("{}", anyhow!("{} and {}", 1, 2)), "1 and 2");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn io() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/file")?;
            Ok(s)
        }
        assert!(io().is_err());
    }

    #[test]
    fn with_context_is_lazy() {
        let called = std::cell::Cell::new(false);
        let ok: std::result::Result<u32, std::io::Error> = Ok(7);
        let v = ok
            .with_context(|| {
                called.set(true);
                "ctx"
            })
            .unwrap();
        assert_eq!(v, 7);
        assert!(!called.get(), "context closure must not run on Ok");
    }

    #[test]
    fn bail_and_ensure() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert!(f(3).is_err());
        assert!(format!("{:#}", f(99).unwrap_err()).contains("99"));
    }

    #[test]
    fn debug_shows_cause_chain() {
        let e = fails().context("ctx").unwrap_err();
        let d = format!("{e:?}");
        assert!(d.contains("ctx") && d.contains("Caused by") && d.contains("root 42"));
    }
}
