//! Overload control + fallback chains, end to end.
//!
//! The invariants under test, in rough order of importance:
//! * **Flags off is PR-parity**: with `pool.admission.enabled = false`
//!   and no chain routes, the dispatch path is the legacy one — token
//!   streams must be bit-identical to a run with the overload machinery
//!   switched on but inert, on both substrates.
//! * **Exactly-once resolution**: every request resolves exactly once —
//!   a completion or one typed error — under shedding, escalation, and
//!   replica SIGKILL.
//! * **Priority protection**: under 2× overload only batch work sheds;
//!   interactive requests all complete.
//! * **Bounded retries**: chain re-dispatches never exceed the
//!   gateway-wide retry-budget ratio.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use pick_and_spin::config::{Config, Priority, SubstrateKind};
use pick_and_spin::gateway::{
    CompletionError, CompletionRequest, FailureKind, LiveStack,
};
use pick_and_spin::testkit::wait_until;

const WORKER_BIN: &str = env!("CARGO_BIN_EXE_pick-and-spin");

/// Easy prompts (keyword complexity 0) route to the small tier.
fn easy_prompt(i: usize) -> String {
    format!("what is {i} plus {i}?")
}

/// Hard prompts (keyword complexity 2) route to the large tier.
fn hard_prompt(i: usize) -> String {
    format!("prove that series {i} converges and derive the bound")
}

fn base_cfg() -> Config {
    let mut cfg = Config::default();
    cfg.pool.replicas = [1, 1, 1];
    cfg.pool.max_inflight = 1;
    cfg.pool.flush_timeout_s = 0.003;
    cfg.pool.scale_interval_s = 0.02;
    // No scale-down noise during the experiments.
    cfg.orchestrator.idle_timeout_s = 3600.0;
    cfg
}

/// Overload features configured but inert: admission on with an
/// unreachable watermark, chains on with a score floor that never
/// triggers. Light traffic must be token-identical to flags-off.
fn inert_overload_cfg() -> Config {
    let mut cfg = base_cfg();
    cfg.pool.max_inflight = 8;
    cfg.pool.admission.enabled = true;
    cfg.pool.admission.watermark = 1.0;
    cfg.pool.chains.routes = [vec![1, 2], vec![2], vec![]];
    cfg.pool.chains.score_floor = 0.0;
    cfg.pool.chains.backoff_base_s = 0.0;
    cfg.pool.chains.retry_budget_ratio = 2.0;
    cfg
}

fn process_cfg(mut cfg: Config) -> Config {
    cfg.pool.substrate = SubstrateKind::Process;
    cfg.pool.worker_bin = Some(WORKER_BIN.to_string());
    cfg.pool.worker_log_dir = std::env::var("PS_WORKER_LOG_DIR").ok();
    cfg
}

/// Serve `n` prompts concurrently; return index → token stream.
fn serve(
    stack: &Arc<LiveStack>,
    n: usize,
    max_new: usize,
) -> std::collections::BTreeMap<usize, Vec<i32>> {
    let handles: Vec<_> = (0..n)
        .map(|i| {
            let s = Arc::clone(stack);
            std::thread::spawn(move || {
                (i, s.complete(&easy_prompt(i), max_new).expect("request").tokens)
            })
        })
        .collect();
    handles
        .into_iter()
        .map(|h| h.join().expect("request thread"))
        .collect()
}

#[test]
fn flags_off_is_token_identical_to_inert_overload_thread_substrate() {
    let n = 16;
    let mut plain_cfg = base_cfg();
    plain_cfg.pool.max_inflight = 8;
    let plain_stack = Arc::new(LiveStack::start_sim(&plain_cfg).unwrap());
    let plain = serve(&plain_stack, n, 16);
    // Flags off: no overload series beyond the always-on budget gauge.
    let snap = plain_stack.metrics_snapshot();
    assert!(!snap.iter().any(|(k, _)| k.starts_with("ps_shed_total")));
    assert!(!snap.iter().any(|(k, _)| k.starts_with("ps_chain_")));
    assert!(snap
        .iter()
        .any(|(k, v)| k == "ps_retry_budget_ratio" && *v == 0.0));
    drop(plain_stack);

    let stack = Arc::new(LiveStack::start_sim(&inert_overload_cfg()).unwrap());
    let wrapped = serve(&stack, n, 16);
    assert_eq!(plain, wrapped, "inert overload control changed tokens");
    assert_eq!(stack.metrics.errors.load(Ordering::Relaxed), 0);
    assert_eq!(stack.metrics.retries_issued.load(Ordering::Relaxed), 0);
    for row in &stack.metrics.shed_total {
        for c in row {
            assert_eq!(c.load(Ordering::Relaxed), 0);
        }
    }
}

#[test]
fn flags_off_is_token_identical_to_inert_overload_process_substrate() {
    // Same parity check across the RPC data plane: the process pool with
    // the whole overload machine switched on (but inert) must reproduce
    // the thread pool's flags-off completions exactly.
    let n = 12;
    let mut plain_cfg = base_cfg();
    plain_cfg.pool.max_inflight = 8;
    let plain_stack = Arc::new(LiveStack::start_sim(&plain_cfg).unwrap());
    let plain = serve(&plain_stack, n, 12);
    drop(plain_stack);

    let stack = Arc::new(
        LiveStack::start_sim(&process_cfg(inert_overload_cfg())).unwrap(),
    );
    let wrapped = serve(&stack, n, 12);
    assert_eq!(plain, wrapped, "inert overload control changed tokens");
    assert_eq!(stack.metrics.errors.load(Ordering::Relaxed), 0);
}

#[test]
fn score_floor_escalates_to_a_stronger_tier() {
    // Every tier's relevance on these prompts sits below a 0.99 floor,
    // so any chain-wrapped completion escalates along its route and the
    // caller's answer comes from the route's last, strongest rung.
    let mut cfg = base_cfg();
    cfg.pool.max_inflight = 8;
    cfg.pool.chains.routes = [vec![2], vec![2], vec![]];
    cfg.pool.chains.score_floor = 0.99;
    cfg.pool.chains.max_retries = 2;
    cfg.pool.chains.backoff_base_s = 0.0;
    cfg.pool.chains.retry_budget_ratio = 10.0;
    let stack = Arc::new(LiveStack::start_sim(&cfg).unwrap());
    let n = 8;
    let handles: Vec<_> = (0..n)
        .map(|i| {
            let s = Arc::clone(&stack);
            std::thread::spawn(move || s.complete(&easy_prompt(i), 8))
        })
        .collect();
    for h in handles {
        let r = h.join().unwrap().expect("chained completion");
        assert_eq!(r.tier, "large", "low-score hop was not escalated");
        assert!(!r.tokens.is_empty());
    }
    let m = &stack.metrics;
    let escalated: u64 = m
        .chain_escalated
        .iter()
        .map(|c| c.load(Ordering::Relaxed))
        .sum();
    assert!(escalated >= 1, "no escalation recorded");
    assert_eq!(
        escalated,
        m.retries_issued.load(Ordering::Relaxed),
        "every retry here is a quality escalation"
    );
    let snap = stack.metrics_snapshot();
    assert!(snap
        .iter()
        .any(|(k, v)| k.starts_with("ps_chain_escalated_total{route=") && *v >= 1.0));
    assert_eq!(m.errors.load(Ordering::Relaxed), 0);
}

#[test]
fn admission_sheds_batch_only_and_interactive_completes() {
    // ~3× the watermark in batch work against one slow replica chain,
    // plus a trickle of interactive traffic: the gate must shed batch
    // (with a Retry-After hint), never interactive, and every request
    // must resolve exactly once.
    let mut cfg = base_cfg();
    cfg.pool.queue_capacity = 64;
    cfg.pool.admission.enabled = true;
    cfg.pool.admission.watermark = 0.125; // shed past 8 queued per tier
    let stack = Arc::new(LiveStack::start_sim(&cfg).unwrap());
    let n_batch = 48;
    let n_inter = 8;
    let batch: Vec<_> = (0..n_batch)
        .map(|i| {
            let s = Arc::clone(&stack);
            std::thread::spawn(move || {
                s.complete_request(
                    CompletionRequest::new(hard_prompt(i))
                        .max_tokens(32)
                        .priority(Priority::Batch),
                )
            })
        })
        .collect();
    // Give the flood a head start so the backlog is past the watermark.
    std::thread::sleep(Duration::from_millis(30));
    let inter: Vec<_> = (0..n_inter)
        .map(|i| {
            let s = Arc::clone(&stack);
            std::thread::spawn(move || {
                s.complete_request(
                    CompletionRequest::new(hard_prompt(1000 + i))
                        .max_tokens(8)
                        .priority(Priority::Interactive),
                )
            })
        })
        .collect();
    for h in inter {
        let r = h.join().unwrap().expect("interactive must never shed");
        assert!(!r.tokens.is_empty());
    }
    let (mut ok, mut shed) = (0usize, 0usize);
    for h in batch {
        match h.join().unwrap() {
            Ok(r) => {
                assert!(!r.tokens.is_empty());
                ok += 1;
            }
            Err(e) => {
                let ce = e
                    .downcast_ref::<CompletionError>()
                    .expect("untyped overload failure");
                assert!(
                    matches!(ce.kind, FailureKind::Shed | FailureKind::QueueFull),
                    "unexpected failure kind: {:?}",
                    ce.kind
                );
                assert!(
                    ce.retry_after_s.unwrap_or(0.0) > 0.0,
                    "shed without a Retry-After hint"
                );
                shed += 1;
            }
        }
    }
    assert_eq!(ok + shed, n_batch, "a batch request went unresolved");
    assert!(shed >= 1, "2x overload shed nothing");
    let m = &stack.metrics;
    // Interactive and standard rows stay empty — only batch sheds.
    for ti in 0..3 {
        assert_eq!(m.shed_total[0][ti].load(Ordering::Relaxed), 0);
        assert_eq!(m.shed_total[1][ti].load(Ordering::Relaxed), 0);
    }
    let batch_shed: u64 =
        (0..3).map(|ti| m.shed_total[2][ti].load(Ordering::Relaxed)).sum();
    let backlog_rejects = m.admission_rejected_backlog.load(Ordering::Relaxed);
    assert_eq!(
        batch_shed + backlog_rejects,
        shed as u64,
        "shed accounting must match caller-visible rejections exactly"
    );
    let snap = stack.metrics_snapshot();
    assert!(snap
        .iter()
        .any(|(k, v)| k.starts_with("ps_shed_total{priority=\"batch\"") && *v >= 1.0));
    assert!(snap
        .iter()
        .any(|(k, _)| k.starts_with("ps_queue_wait_hist_seconds{priority=\"interactive\"")));
}

#[test]
fn expired_deadlines_are_dropped_at_dequeue() {
    // A deadline far shorter than the backlog's drain time: queued work
    // expires before a replica reaches it and is dropped at dequeue —
    // counted as expired shed — instead of burning decode steps.
    let cfg = base_cfg();
    let stack = Arc::new(LiveStack::start_sim(&cfg).unwrap());
    let n = 32;
    let handles: Vec<_> = (0..n)
        .map(|i| {
            let s = Arc::clone(&stack);
            std::thread::spawn(move || {
                s.complete_request(
                    CompletionRequest::new(hard_prompt(i))
                        .max_tokens(48)
                        .deadline_s(0.05),
                )
            })
        })
        .collect();
    let mut failed = 0usize;
    for h in handles {
        match h.join().unwrap() {
            Ok(r) => assert!(!r.tokens.is_empty()),
            Err(e) => {
                let ce = e
                    .downcast_ref::<CompletionError>()
                    .expect("untyped deadline failure");
                assert!(
                    matches!(
                        ce.kind,
                        FailureKind::Timeout | FailureKind::DeadlineExpired
                    ),
                    "unexpected failure kind: {:?}",
                    ce.kind
                );
                failed += 1;
            }
        }
    }
    assert!(failed >= 1, "a 50ms deadline survived a 32-deep backlog");
    assert!(
        wait_until(Duration::from_secs(5), || {
            stack.metrics.shed_expired.load(Ordering::Relaxed) >= 1
        }),
        "no expired-deadline drop was recorded"
    );
    let snap = stack.metrics_snapshot();
    assert!(snap
        .iter()
        .any(|(k, v)| k == "ps_shed_total{reason=\"expired\"}" && *v >= 1.0));
}

#[test]
fn sigkill_under_chain_loses_zero_completions() {
    // SIGKILL the small tier's only worker while chained traffic is in
    // flight over the process substrate: loss-free requeue (and, for
    // anything that surfaces as a typed replica failure, the chain's
    // escalation) must land every completion.
    let cfg = process_cfg(inert_overload_cfg());
    let stack = Arc::new(LiveStack::start_sim(&cfg).unwrap());
    let n = 24usize;
    let handles: Vec<_> = (0..n)
        .map(|i| {
            let s = Arc::clone(&stack);
            std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(i as u64 * 3));
                s.complete(&easy_prompt(i), 16)
            })
        })
        .collect();
    assert!(
        wait_until(Duration::from_secs(10), || stack.slots_in_use() > 0),
        "traffic never started decoding"
    );
    assert!(
        stack.inject_replica_failure(0),
        "no Ready small-tier replica to kill"
    );
    for h in handles {
        let r = h
            .join()
            .unwrap()
            .expect("completion lost across the SIGKILL");
        assert!(!r.tokens.is_empty());
    }
    assert!(
        wait_until(Duration::from_secs(10), || {
            stack.metrics.incidents.load(Ordering::Relaxed) >= 1
        }),
        "the kill never surfaced as an incident"
    );
}

#[test]
fn chaos_every_request_resolves_once_and_retries_stay_bounded() {
    // Everything at once: admission on with a tight watermark, chains
    // with a score floor and degrade enabled, mixed priorities, some
    // short deadlines, and a replica kill mid-run. The properties:
    // every request resolves exactly once (one Ok or one *typed* Err),
    // and issued retries never exceed the retry-budget ratio.
    let mut cfg = base_cfg();
    cfg.pool.max_inflight = 2;
    cfg.pool.queue_capacity = 32;
    cfg.pool.admission.enabled = true;
    cfg.pool.admission.watermark = 0.5;
    cfg.pool.chains.routes = [vec![1, 2], vec![2], vec![]];
    cfg.pool.chains.score_floor = 0.9;
    cfg.pool.chains.max_retries = 2;
    cfg.pool.chains.backoff_base_s = 0.001;
    cfg.pool.chains.retry_budget_ratio = 0.5;
    cfg.pool.chains.degrade = true;
    let stack = Arc::new(LiveStack::start_sim(&cfg).unwrap());
    let n = 60usize;
    let handles: Vec<_> = (0..n)
        .map(|i| {
            let s = Arc::clone(&stack);
            std::thread::spawn(move || {
                let mut req = CompletionRequest::new(easy_prompt(i))
                    .max_tokens(12)
                    .priority(Priority::ALL[i % 3]);
                if i % 7 == 0 {
                    req = req.deadline_s(0.2);
                }
                s.complete_request(req)
            })
        })
        .collect();
    std::thread::sleep(Duration::from_millis(40));
    // Kill whatever is serving; recovery redeploys it mid-chaos.
    let _ = stack.inject_replica_failure(1);
    let (mut ok, mut err) = (0usize, 0usize);
    for h in handles {
        match h.join().expect("request thread must resolve") {
            Ok(r) => {
                assert!(!r.tokens.is_empty());
                ok += 1;
            }
            Err(e) => {
                assert!(
                    e.downcast_ref::<CompletionError>().is_some(),
                    "untyped failure escaped the gateway: {e:#}"
                );
                err += 1;
            }
        }
    }
    assert_eq!(ok + err, n, "a request resolved zero or two times");
    let m = &stack.metrics;
    let fresh = m.fresh_jobs.load(Ordering::Relaxed).max(1);
    let retries = m.retries_issued.load(Ordering::Relaxed);
    assert!(
        retries as f64 <= 0.5 * fresh as f64 + 1.0,
        "retry budget exceeded: {retries} retries vs {fresh} fresh"
    );
    assert_eq!(m.requests.load(Ordering::Relaxed), n as u64);
}

#[test]
fn http_maps_overload_failures_to_429_with_retry_after() {
    use pick_and_spin::gateway::http::http_request_full;
    use pick_and_spin::gateway::serve_http;

    // A 4-deep queue against 16 concurrent batch posts: the gate must
    // answer the overflow with 429 + Retry-After, not 500.
    let mut cfg = base_cfg();
    cfg.pool.queue_capacity = 4;
    cfg.pool.admission.enabled = true;
    cfg.pool.admission.watermark = 0.5;
    let stack = Arc::new(LiveStack::start_sim(&cfg).unwrap());
    let srv = serve_http(Arc::clone(&stack), 0, 8).unwrap();
    let port = srv.port;
    let handles: Vec<_> = (0..16)
        .map(|i| {
            std::thread::spawn(move || {
                http_request_full(
                    port,
                    "POST",
                    "/v1/completions",
                    Some(&format!(
                        r#"{{"prompt": "prove that series {i} converges and derive the bound",
                            "max_tokens": 32, "priority": "batch"}}"#
                    )),
                )
                .unwrap()
            })
        })
        .collect();
    let mut saw_ok = false;
    let mut saw_429 = false;
    for h in handles {
        let (status, headers, body) = h.join().unwrap();
        match status {
            200 => saw_ok = true,
            429 => {
                let ra = headers
                    .iter()
                    .find(|(k, _)| k.eq_ignore_ascii_case("retry-after"))
                    .map(|(_, v)| v.clone())
                    .unwrap_or_else(|| panic!("429 without Retry-After: {body}"));
                assert!(ra.parse::<f64>().unwrap() >= 1.0);
                saw_429 = true;
            }
            other => panic!("unexpected status {other}: {body}"),
        }
    }
    assert!(saw_ok, "everything was rejected");
    assert!(saw_429, "4-deep queue never pushed back on 16 posts");
    // An unknown priority label is a client error, not a served request.
    let (status, _, _) = http_request_full(
        port,
        "POST",
        "/v1/completions",
        Some(r#"{"prompt": "what is 1 plus 1?", "max_tokens": 4, "priority": "urgent"}"#),
    )
    .unwrap();
    assert_eq!(status, 500);
    srv.stop();
}
