//! Integration tests over the real AOT artifacts: HLO round-trip, weight
//! upload, classifier inference, LM prefill + decode — the full
//! Python-compile → Rust-serve bridge.
//!
//! Skipped (pass trivially) when `artifacts/` hasn't been built.

use pick_and_spin::router::Classifier;
use pick_and_spin::runtime::Runtime;
use pick_and_spin::tokenizer;

fn artifacts_dir() -> Option<String> {
    let dir = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"));
    if std::path::Path::new(&format!("{dir}/manifest.json")).exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts not built");
        None
    }
}

#[test]
fn manifest_loads_and_covers_tiers() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir).unwrap();
    for tier in ["small", "medium", "large"] {
        let info = rt.manifest.model(tier).unwrap();
        assert!(info.param_count > 0);
        rt.manifest.module(&format!("lm_{tier}_prefill_b1")).unwrap();
        rt.manifest.module(&format!("lm_{tier}_decode_b4")).unwrap();
    }
    let cls = rt.manifest.model("classifier").unwrap();
    assert!(cls.val_accuracy.unwrap() >= 0.95);
}

#[test]
fn tokenizer_parity_with_python() {
    let Some(dir) = artifacts_dir() else { return };
    let j = pick_and_spin::util::json::Json::from_file(
        &format!("{dir}/tokenizer_parity.json")).unwrap();
    assert_eq!(j.rusize("vocab").unwrap(), tokenizer::VOCAB as usize);
    for case in j.rarr("cases").unwrap() {
        let text = case.rstr("text").unwrap();
        let want: Vec<i32> = case
            .rarr("ids")
            .unwrap()
            .iter()
            .map(|v| v.as_i64().unwrap() as i32)
            .collect();
        assert_eq!(tokenizer::encode(text, want.len()), want, "text: {text:?}");
    }
    for (word, id) in j.req("word_ids").unwrap().as_obj().unwrap() {
        assert_eq!(tokenizer::word_id(word) as i64, id.as_i64().unwrap());
    }
}

#[test]
fn classifier_engine_routes_complexity() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::load(&dir).unwrap();
    let mut cls = rt.classifier_engine().unwrap();

    let (lo, lo_conf) = cls.classify("what is 7 plus 3?").unwrap();
    assert_eq!(lo, 0, "easy prompt misrouted (conf {lo_conf})");

    let (hi, _) = cls
        .classify("prove that the sequence defined by f(n) = 3n + 7 is \
                   monotonic for all natural numbers n.")
        .unwrap();
    assert_eq!(hi, 2);

    // Probabilities are a distribution.
    let p = cls.probs("write a python function that reverses a list").unwrap();
    let sum: f64 = p.iter().sum();
    assert!((sum - 1.0).abs() < 1e-4, "probs {p:?}");
}

#[test]
fn lm_engine_generates_deterministically() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::load(&dir).unwrap();
    let lm = rt.lm_engine("small", &[1]).unwrap();
    let g1 = lm.generate("natalia sold 12 apples in april", 8).unwrap();
    let g2 = lm.generate("natalia sold 12 apples in april", 8).unwrap();
    assert_eq!(g1.tokens, g2.tokens);
    assert_eq!(g1.tokens.len(), 8);
    assert!(g1.tokens.iter().all(|&t| (0..4096).contains(&t)));
    assert!(g1.ttft_s > 0.0 && g1.ttft_s <= g1.latency_s);
    assert_eq!(g1.prompt_tokens, 6);
}

#[test]
fn lm_batch_decode_matches_solo() {
    // The continuous-batching invariant, end-to-end through PJRT:
    // a sequence decoded in a batch of 4 must produce the same tokens
    // as decoded alone.
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::load(&dir).unwrap();
    let lm = rt.lm_engine("small", &[1, 4]).unwrap();
    let prompts = vec![
        "what is 3 plus 7?",
        "prove that the function is monotonic step by step",
        "natalia sold 12 apples",
        "write a python function that reverses a linked list",
    ];
    let batch = lm.generate_batch(&prompts, 6).unwrap();
    for (p, bg) in prompts.iter().zip(&batch) {
        let solo = lm.generate(p, 6).unwrap();
        let n = 6.min(solo.tokens.len()).min(bg.tokens.len());
        assert_eq!(&solo.tokens[..n], &bg.tokens[..n], "prompt {p:?}");
    }
}

#[test]
fn medium_and_large_tiers_execute() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::load(&dir).unwrap();
    for tier in ["medium", "large"] {
        let lm = rt.lm_engine(tier, &[1]).unwrap();
        let g = lm.generate("explain why plate tectonics occurs", 4).unwrap();
        assert_eq!(g.tokens.len(), 4, "tier {tier}");
    }
}

#[test]
fn larger_tiers_are_slower_per_token() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::load(&dir).unwrap();
    let small = rt.lm_engine("small", &[1]).unwrap();
    let large = rt.lm_engine("large", &[1]).unwrap();
    // Warm up both, then measure.
    small.generate("warm up", 4).unwrap();
    large.generate("warm up", 4).unwrap();
    let gs = small.generate("compare and contrast two theories", 16).unwrap();
    let gl = large.generate("compare and contrast two theories", 16).unwrap();
    assert!(
        gl.latency_s > gs.latency_s,
        "large {:.4}s should exceed small {:.4}s",
        gl.latency_s,
        gs.latency_s
    );
}
