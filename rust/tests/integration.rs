//! Cross-module integration: router → scoring → registry → orchestrator
//! over the real template library, no PJRT required.

use pick_and_spin::baselines::{SelectionPolicy, Selector};
use pick_and_spin::config::{Profile, RouterMode};
use pick_and_spin::models::zoo;
use pick_and_spin::registry::Registry;
use pick_and_spin::router::keyword::KeywordRouter;
use pick_and_spin::router::Router;
use pick_and_spin::scoring::Weights;
use pick_and_spin::workload::{Generator, OracleClassifier, TemplateLibrary};

fn lib() -> Option<TemplateLibrary> {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/data/templates.json");
    if std::path::Path::new(path).exists() {
        Some(TemplateLibrary::load(path).unwrap())
    } else {
        eprintln!("skipping: data/templates.json not built");
        None
    }
}

#[test]
fn keyword_router_beats_chance_on_real_templates() {
    let Some(lib) = lib() else { return };
    let mut gen = Generator::new(&lib, 5);
    let mut router = KeywordRouter::new();
    let (mut hits, n) = (0usize, 3000);
    for _ in 0..n {
        let p = gen.prompt_mixed();
        if router.route(&p.text).unwrap().complexity == p.complexity {
            hits += 1;
        }
    }
    let acc = hits as f64 / n as f64;
    assert!(acc > 0.45, "keyword accuracy {acc} not better than chance");
    assert!(acc < 0.98, "keyword accuracy {acc} suspiciously perfect");
}

#[test]
fn oracle_classifier_accuracy_tracks_error_rate() {
    let Some(lib) = lib() else { return };
    let mut gen = Generator::new(&lib, 6);
    use pick_and_spin::router::Classifier;
    let mut oracle = OracleClassifier::new(lib.clone(), 0.05, 1);
    let (mut hits, n) = (0usize, 2000);
    for _ in 0..n {
        let p = gen.prompt_mixed();
        if oracle.classify(&p.text).unwrap().0 == p.complexity {
            hits += 1;
        }
    }
    let acc = hits as f64 / n as f64;
    assert!((acc - 0.95).abs() < 0.03, "oracle accuracy {acc}");
}

#[test]
fn full_pipeline_routes_by_complexity() {
    let Some(lib) = lib() else { return };
    let mut registry = Registry::new(&zoo(), 300.0);
    for s in &mut registry.services {
        s.ready_replicas = 1;
    }
    let mut selector = Selector::new(
        SelectionPolicy::MultiObjective,
        Weights::from_profile(&Profile::BALANCED),
        3,
    );
    let mut gen = Generator::new(&lib, 9);
    use pick_and_spin::router::Classifier;
    let mut oracle = OracleClassifier::new(lib.clone(), 0.0, 2);
    // Average capability of the chosen model must rise with complexity.
    let mut cap_by_class = [0.0f64; 3];
    let mut count_by_class = [0usize; 3];
    for _ in 0..600 {
        let p = gen.prompt_mixed();
        let (c, conf) = oracle.classify(&p.text).unwrap();
        let class = pick_and_spin::router::Classification {
            complexity: c,
            confidence: conf,
            mode: RouterMode::Hybrid,
            overhead_s: 0.0,
        };
        let sid = selector
            .select(&registry, &class, 30.0, 80.0, |_| 30.0)
            .unwrap();
        cap_by_class[c] += registry.get(sid).spec.capability[2];
        count_by_class[c] += 1;
    }
    let avg: Vec<f64> = (0..3)
        .map(|c| cap_by_class[c] / count_by_class[c].max(1) as f64)
        .collect();
    assert!(avg[2] > avg[0],
            "hard prompts should land on stronger models: {avg:?}");
}
