//! Learned (bandit) routing, end to end on the live stack.
//!
//! The invariants under test:
//! * **Off is PR-parity**: with `pool.routing.bandit.enabled = false`
//!   (the default) the learner is never armed, `/metrics` exports no
//!   `ps_bandit_*` series, and token streams are bit-identical to a
//!   bandit-on run — on the thread substrate AND the process substrate
//!   (the engines' token streams are prompt-seeded, so identical
//!   prompts must yield identical tokens whichever tier serves them).
//! * **On, the loop closes**: completions feed the learner and the
//!   exposition carries `ps_bandit_selected_total`,
//!   `ps_bandit_reward_total`, and per-arm `ps_bandit_estimate` gauges.

use std::collections::BTreeMap;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use pick_and_spin::config::{Config, SubstrateKind};
use pick_and_spin::gateway::{CompletionRequest, LiveStack};
use pick_and_spin::testkit::wait_until;

const WORKER_BIN: &str = env!("CARGO_BIN_EXE_pick-and-spin");

fn prompt(i: usize) -> String {
    format!("what is {i} plus {i}?")
}

fn base_cfg() -> Config {
    let mut cfg = Config::default();
    cfg.pool.replicas = [1, 1, 1];
    cfg.pool.max_inflight = 8;
    cfg.pool.flush_timeout_s = 0.003;
    cfg.pool.scale_interval_s = 0.02;
    cfg.orchestrator.idle_timeout_s = 3600.0;
    cfg
}

fn bandit_cfg() -> Config {
    let mut cfg = base_cfg();
    cfg.pool.routing.bandit.enabled = true;
    // Small warm-up so a short test exercises the post-exploration
    // (greedy/epsilon) regime too.
    cfg.pool.routing.bandit.min_samples = 2;
    cfg
}

fn process_cfg(mut cfg: Config) -> Config {
    cfg.pool.substrate = SubstrateKind::Process;
    cfg.pool.worker_bin = Some(WORKER_BIN.to_string());
    cfg.pool.worker_log_dir = std::env::var("PS_WORKER_LOG_DIR").ok();
    cfg
}

/// Serve `n` prompts concurrently; return index → token stream.
fn serve(stack: &Arc<LiveStack>, n: usize, max_new: usize) -> BTreeMap<usize, Vec<i32>> {
    let handles: Vec<_> = (0..n)
        .map(|i| {
            let s = Arc::clone(stack);
            std::thread::spawn(move || {
                let req = CompletionRequest::new(prompt(i)).max_tokens(max_new);
                (i, s.complete_request(req).expect("request").tokens)
            })
        })
        .collect();
    handles
        .into_iter()
        .map(|h| h.join().expect("request thread"))
        .collect()
}

fn bandit_series(stack: &LiveStack) -> Vec<(String, f64)> {
    stack
        .metrics_snapshot()
        .into_iter()
        .filter(|(k, _)| k.starts_with("ps_bandit_"))
        .collect()
}

#[test]
fn bandit_off_is_default_and_tokens_match_bandit_on_thread_substrate() {
    let n = 16;
    let plain_stack = Arc::new(LiveStack::start_sim(&base_cfg()).unwrap());
    let plain = serve(&plain_stack, n, 16);
    // Off (the default): the learner is never armed and the exposition
    // carries no ps_bandit series at all.
    assert!(plain_stack.metrics.bandit.get().is_none());
    assert!(bandit_series(&plain_stack).is_empty());
    assert_eq!(plain_stack.metrics.errors.load(Ordering::Relaxed), 0);
    drop(plain_stack);

    let stack = Arc::new(LiveStack::start_sim(&bandit_cfg()).unwrap());
    let learned = serve(&stack, n, 16);
    // Token streams are prompt-seeded: learned tier choices must not
    // change a single token of any response.
    assert_eq!(plain, learned, "bandit routing changed the token stream");
    assert_eq!(stack.metrics.errors.load(Ordering::Relaxed), 0);
    assert_eq!(stack.metrics.completed.load(Ordering::Relaxed), n as u64);
    // On: selections were recorded at route time; rewards land as the
    // replica loops feed completions back (racing us — wait).
    assert!(
        wait_until(Duration::from_secs(5), || {
            let s = bandit_series(&stack);
            s.iter().any(|(k, _)| k.starts_with("ps_bandit_selected_total{tier="))
                && s.iter().any(|(k, _)| k.starts_with("ps_bandit_reward_total{tier="))
                && s.iter().any(|(k, _)| k.starts_with("ps_bandit_estimate{class="))
        }),
        "bandit series never appeared: {:?}",
        bandit_series(&stack)
    );
    let selected: f64 = bandit_series(&stack)
        .iter()
        .filter(|(k, _)| k.starts_with("ps_bandit_selected_total"))
        .map(|(_, v)| v)
        .sum();
    assert_eq!(selected as u64, n as u64, "every request routes through the learner");
}

#[test]
fn bandit_off_and_on_tokens_match_on_process_substrate() {
    let n = 12;
    let plain_stack =
        Arc::new(LiveStack::start_sim(&process_cfg(base_cfg())).unwrap());
    let plain = serve(&plain_stack, n, 12);
    assert!(bandit_series(&plain_stack).is_empty());
    assert_eq!(plain_stack.metrics.errors.load(Ordering::Relaxed), 0);
    drop(plain_stack);

    let stack = Arc::new(LiveStack::start_sim(&process_cfg(bandit_cfg())).unwrap());
    let learned = serve(&stack, n, 12);
    assert_eq!(
        plain, learned,
        "bandit routing changed process-substrate token streams"
    );
    assert_eq!(stack.metrics.errors.load(Ordering::Relaxed), 0);
    // The feedback loop closes across the RPC wire: worker completions
    // come back through the supervisor pumps and reach the learner.
    assert!(
        wait_until(Duration::from_secs(10), || {
            bandit_series(&stack)
                .iter()
                .any(|(k, _)| k.starts_with("ps_bandit_reward_total{tier="))
        }),
        "no reward crossed the wire: {:?}",
        bandit_series(&stack)
    );
}
