//! Cross-tier speculative decoding end-to-end: small-tier drafts,
//! big-tier batched verify, on both substrates.
//!
//! The acceptance model lives in the sim engine (deterministic verdict
//! streams at `pool.speculative.sim_accept`), and the sim engine drafts
//! by lookahead on its own token stream — so speculation changes *when*
//! tokens land, never *which* tokens land. That makes the strongest
//! possible integration check cheap: a speculative run must produce
//! bit-identical completions to a plain run of the same prompts, while
//! the spec counters prove the draft/verify path actually engaged. The
//! recovery test SIGKILLs the draft tier mid-stream and requires every
//! completion to survive via the plain-decode fallback.

use std::collections::BTreeMap;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use pick_and_spin::config::{Config, SubstrateKind};
use pick_and_spin::gateway::LiveStack;
use pick_and_spin::testkit::wait_until;

const WORKER_BIN: &str = env!("CARGO_BIN_EXE_pick-and-spin");

/// Hard prompts (keyword complexity 2) route to the large tier, which is
/// a verify tier under `draft_tier = 0`.
fn hard_prompt(i: usize) -> String {
    format!("prove that series {i} converges and derive the bound")
}

fn scfg(enabled: bool, accept: f64) -> Config {
    let mut cfg = Config::default();
    cfg.pool.replicas = [1, 1, 1];
    cfg.pool.max_inflight = 8;
    cfg.pool.flush_timeout_s = 0.003;
    cfg.pool.scale_interval_s = 0.02;
    // No scale-down noise during the experiments.
    cfg.orchestrator.idle_timeout_s = 3600.0;
    cfg.pool.speculative.enabled = enabled;
    cfg.pool.speculative.draft_tier = 0;
    cfg.pool.speculative.draft_tokens = 4;
    cfg.pool.speculative.sim_accept = accept;
    cfg
}

fn pcfg(enabled: bool, accept: f64) -> Config {
    let mut cfg = scfg(enabled, accept);
    cfg.pool.substrate = SubstrateKind::Process;
    cfg.pool.worker_bin = Some(WORKER_BIN.to_string());
    cfg.pool.worker_log_dir = std::env::var("PS_WORKER_LOG_DIR").ok();
    cfg
}

/// Serve every prompt and return prompt index → token stream.
fn serve(stack: &Arc<LiveStack>, n: usize, max_new: usize) -> BTreeMap<usize, Vec<i32>> {
    let handles: Vec<_> = (0..n)
        .map(|i| {
            let s = Arc::clone(stack);
            std::thread::spawn(move || {
                (i, s.complete(&hard_prompt(i), max_new).expect("request").tokens)
            })
        })
        .collect();
    handles
        .into_iter()
        .map(|h| h.join().expect("request thread"))
        .collect()
}

/// Wait for the router's first control pass to publish draft-tier
/// availability, then serve; speculation engages mid-run at the latest.
fn serve_speculative(
    stack: &Arc<LiveStack>,
    n: usize,
    max_new: usize,
) -> BTreeMap<usize, Vec<i32>> {
    std::thread::sleep(Duration::from_millis(200));
    serve(stack, n, max_new)
}

#[test]
fn speculative_decode_is_token_identical_and_engages_on_the_thread_substrate() {
    let n = 24;
    let plain_stack = Arc::new(LiveStack::start_sim(&scfg(false, 0.0)).unwrap());
    let plain = serve(&plain_stack, n, 24);
    drop(plain_stack);

    let stack = Arc::new(LiveStack::start_sim(&scfg(true, 0.7)).unwrap());
    let spec = serve_speculative(&stack, n, 24);
    assert_eq!(plain, spec, "speculation must never change the token stream");
    assert!(
        wait_until(Duration::from_secs(10), || {
            stack.metrics.spec_drafted_tokens.load(Ordering::Relaxed) > 0
                && stack.metrics.spec_accepted_tokens.load(Ordering::Relaxed) > 0
        }),
        "speculation never engaged: drafted={} accepted={}",
        stack.metrics.spec_drafted_tokens.load(Ordering::Relaxed),
        stack.metrics.spec_accepted_tokens.load(Ordering::Relaxed),
    );
    assert_eq!(stack.metrics.errors.load(Ordering::Relaxed), 0);

    // The whole plane is visible at /metrics, including the per-tier
    // acceptance-rate gauge for the verify tier that served the prompts.
    let snap = stack.metrics_snapshot();
    let get = |name: &str| {
        snap.iter()
            .find(|(k, _)| k == name)
            .map(|(_, v)| *v)
            .unwrap_or_else(|| panic!("{name} missing from /metrics"))
    };
    assert!(get("ps_spec_drafted_tokens_total") > 0.0);
    assert!(get("ps_spec_accepted_tokens_total") > 0.0);
    assert!(get("ps_spec_verify_steps_total") > 0.0);
    let rate = snap
        .iter()
        .find(|(k, _)| k.starts_with("ps_spec_accept_rate{tier="))
        .map(|(_, v)| *v)
        .expect("no per-tier acceptance gauge for a tier that drafted");
    assert!(
        rate > 0.0 && rate <= 1.0,
        "acceptance gauge out of range: {rate}"
    );
}

#[test]
fn speculative_decode_disabled_exports_no_spec_series() {
    // Off by default: the plain pool must not even emit the per-tier
    // acceptance gauges (counters stay, pinned at zero).
    let stack = Arc::new(LiveStack::start_sim(&scfg(false, 0.0)).unwrap());
    serve(&stack, 4, 8);
    assert_eq!(stack.metrics.spec_drafted_tokens.load(Ordering::Relaxed), 0);
    assert_eq!(stack.metrics.spec_verify_steps.load(Ordering::Relaxed), 0);
    let snap = stack.metrics_snapshot();
    assert!(snap.iter().any(|(k, v)| k == "ps_spec_drafted_tokens_total" && *v == 0.0));
    assert!(!snap.iter().any(|(k, _)| k.starts_with("ps_spec_accept_rate")));
}

#[test]
fn speculative_decode_is_token_identical_over_the_process_substrate() {
    // Same check across the RPC data plane: the tier-gated PoolWire
    // window, the SpecDraft availability relay, and the heartbeat spec
    // counters all have to work for this to both engage and stay
    // bit-identical. The worker's sim engine seeds its token stream from
    // the prompt, so the process pool must reproduce the thread pool's
    // plain completions exactly.
    let n = 16;
    let plain_stack = Arc::new(LiveStack::start_sim(&scfg(false, 0.0)).unwrap());
    let plain = serve(&plain_stack, n, 16);
    drop(plain_stack);

    let stack = Arc::new(LiveStack::start_sim(&pcfg(true, 0.7)).unwrap());
    let spec = serve_speculative(&stack, n, 16);
    assert_eq!(plain, spec, "speculation must never change the token stream");
    // Counters flow back through worker heartbeats (omitted-when-zero on
    // the wire, so nonzero here proves the v2 spec plane round-trips).
    assert!(
        wait_until(Duration::from_secs(10), || {
            stack.metrics.spec_drafted_tokens.load(Ordering::Relaxed) > 0
                && stack.metrics.spec_verify_steps.load(Ordering::Relaxed) > 0
        }),
        "spec counters never surfaced over the RPC plane: drafted={} steps={}",
        stack.metrics.spec_drafted_tokens.load(Ordering::Relaxed),
        stack.metrics.spec_verify_steps.load(Ordering::Relaxed),
    );
    assert_eq!(stack.metrics.errors.load(Ordering::Relaxed), 0);
}

#[test]
fn draft_tier_sigkill_falls_back_to_plain_decode_without_loss() {
    // Kill the draft tier mid-stream: the router's next control pass
    // drops the availability signal, verify tiers fall back to plain
    // decode, and — the actual requirement — not a single completion is
    // lost or corrupted while the draft tier recovers.
    let n = 32usize;
    let stack = Arc::new(LiveStack::start_sim(&scfg(true, 0.7)).unwrap());
    std::thread::sleep(Duration::from_millis(200));
    let handles: Vec<_> = (0..n)
        .map(|i| {
            let s = Arc::clone(&stack);
            std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(i as u64 * 3));
                s.complete(&hard_prompt(i), 24)
            })
        })
        .collect();
    assert!(
        wait_until(Duration::from_secs(10), || stack.slots_in_use() > 0),
        "traffic never started decoding"
    );
    assert!(
        stack.inject_replica_failure(0),
        "no Ready draft-tier replica to kill"
    );
    for h in handles {
        let r = h
            .join()
            .unwrap()
            .expect("completion lost across the draft-tier failure");
        assert!(!r.tokens.is_empty());
    }
    assert_eq!(stack.metrics.errors.load(Ordering::Relaxed), 0);
    assert_eq!(stack.metrics.completed.load(Ordering::Relaxed), n as u64);
    // The incident was recorded and the draft tier redeployed.
    assert!(
        wait_until(Duration::from_secs(10), || {
            stack.metrics.incidents.load(Ordering::Relaxed) >= 1
                && stack.metrics.recovered.load(Ordering::Relaxed) >= 1
        }),
        "draft-tier incident never recovered"
    );
}
