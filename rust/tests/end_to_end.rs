//! End-to-end: live gateway over compiled artifacts (HTTP in, routed PJRT
//! inference out) and a full simulated experiment, exercising every layer.

use pick_and_spin::config::Config;

fn artifacts_exist() -> bool {
    let ok = std::path::Path::new(
        concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json")).exists();
    if !ok {
        eprintln!("skipping: artifacts not built");
    }
    ok
}

#[test]
fn live_gateway_serves_http() {
    if !artifacts_exist() {
        return;
    }
    use pick_and_spin::gateway::http::http_request;
    use pick_and_spin::gateway::{serve_http, LiveStack};
    use std::sync::Arc;

    let stack = Arc::new(LiveStack::start(&Config::default()).unwrap());
    let srv = serve_http(Arc::clone(&stack), 0, 2).unwrap();

    let (status, body) = http_request(
        srv.port,
        "POST",
        "/v1/completions",
        Some(r#"{"prompt": "prove that the function is monotonic", "max_tokens": 5}"#),
    )
    .unwrap();
    assert_eq!(status, 200, "body: {body}");
    let j = pick_and_spin::util::json::Json::parse(&body).unwrap();
    assert_eq!(j.rstr("tier").unwrap(), "large"); // proof → high tier
    assert!(j.rarr("tokens").unwrap().len() <= 5);

    let (status, metrics) = http_request(srv.port, "GET", "/metrics", None).unwrap();
    assert_eq!(status, 200);
    assert!(metrics.contains("ps_completed_total 1"));

    let (status, _) = http_request(srv.port, "GET", "/healthz", None).unwrap();
    assert_eq!(status, 200);
    srv.stop();
}

#[test]
fn simulated_experiment_reproduces_table1_shape() {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/data/templates.json");
    if !std::path::Path::new(path).exists() {
        eprintln!("skipping: templates not built");
        return;
    }
    use pick_and_spin::baselines::SelectionPolicy;
    use pick_and_spin::sim::{Deployment, SimConfig};
    use pick_and_spin::workload::{OracleClassifier, TemplateLibrary};

    let lib = TemplateLibrary::load(path).unwrap();
    let mut sc = SimConfig::defaults();
    sc.deployment = Deployment::Static;
    sc.policy = SelectionPolicy::RoundRobin;
    sc.n_requests = 12_000;
    sc.rate_qps = 4.0;
    sc.cluster.nodes = 8;
    let cls = Box::new(OracleClassifier::new(lib.clone(), 0.03, 1));
    let rep = pick_and_spin::sim::run(&sc, &lib, cls).unwrap();
    // Paper Table 1: overall 77.1%; shape tolerance ±5 points.
    let rate = rep.success_rate();
    assert!((0.70..=0.83).contains(&rate), "baseline success {rate}");
    // mbpp must be the least reliable benchmark (paper: 69.4%), within noise.
    let agg = pick_and_spin::eval::per_benchmark(&rep.records);
    let mbpp = agg["mbpp"].success_rate();
    let gsm = agg["gsm8k"].success_rate();
    assert!(gsm > mbpp, "gsm8k {gsm} should beat mbpp {mbpp}");
}
