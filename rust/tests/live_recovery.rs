//! Live-path fault injection: kill a replica thread mid-traffic and
//! assert the unified control plane (substrate poll → RecoveryManager →
//! redeploy through `Substrate::provision`) detects the failure, drains
//! the in-flight work without loss on the replacement, and records the
//! incident's measured recovery time at `/metrics` — the live analogue
//! of the simulator's Table 4 runs, driven by the same `Incident` type.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use pick_and_spin::config::Config;
use pick_and_spin::gateway::LiveStack;
use pick_and_spin::testkit::wait_until;

#[test]
fn killed_replica_recovers_and_drains_without_loss() {
    let mut cfg = Config::default();
    cfg.pool.replicas = [2, 1, 1];
    cfg.pool.max_inflight = 8;
    cfg.pool.flush_timeout_s = 0.003;
    cfg.pool.scale_interval_s = 0.05;
    // No scale-down noise during the experiment.
    cfg.orchestrator.idle_timeout_s = 3600.0;
    let stack = Arc::new(LiveStack::start_sim(&cfg).unwrap());
    assert_eq!(stack.active_replicas(), 4);

    // Sustained easy traffic onto the small tier, spread out so the
    // kill lands mid-stream.
    let n = 48u64;
    let handles: Vec<_> = (0..n)
        .map(|i| {
            let s = Arc::clone(&stack);
            std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(i * 2));
                s.complete(&format!("what is {i} plus {i}?"), 24)
            })
        })
        .collect();

    // Kill one small-tier replica once traffic is actually flowing —
    // bounded poll on the slot-occupancy cells, not a fixed sleep (a
    // slow CI scheduler stretches the wait instead of missing the
    // window).
    assert!(
        wait_until(Duration::from_secs(10), || stack.slots_in_use() > 0),
        "traffic never started decoding"
    );
    assert!(
        stack.inject_replica_failure(0),
        "no Ready small-tier replica to kill"
    );

    // Every request still completes: the dead replica's in-flight jobs
    // requeue and drain on the survivor/replacement.
    for h in handles {
        let r = h
            .join()
            .unwrap()
            .expect("request lost across the replica failure");
        assert!(!r.tokens.is_empty());
    }

    // The control plane recorded the incident and closed it when the
    // replacement reached Ready.
    assert!(
        wait_until(Duration::from_secs(10), || {
            stack.metrics.incidents.load(Ordering::Relaxed) >= 1
                && stack.metrics.recovered.load(Ordering::Relaxed) >= 1
        }),
        "incident never recovered: incidents={} recovered={}",
        stack.metrics.incidents.load(Ordering::Relaxed),
        stack.metrics.recovered.load(Ordering::Relaxed)
    );
    assert!(
        wait_until(Duration::from_secs(10), || stack.active_replicas() == 4),
        "the replacement must restore the fleet (have {})",
        stack.active_replicas()
    );

    // The measured recovery time is nonzero and exposed at /metrics.
    let snap = stack.metrics_snapshot();
    let get = |name: &str| {
        snap.iter()
            .find(|(n, _)| n == name)
            .map(|(_, v)| *v)
            .unwrap_or_else(|| panic!("{name} missing from /metrics"))
    };
    assert!(get("ps_incidents_total") >= 1.0);
    assert!(get("ps_recovered_total") >= 1.0);
    assert!(
        get("ps_recovery_seconds_total") > 0.0,
        "recovery_s must be measured and nonzero"
    );
    assert_eq!(stack.metrics.errors.load(Ordering::Relaxed), 0);
    assert_eq!(stack.metrics.completed.load(Ordering::Relaxed), n);
}
