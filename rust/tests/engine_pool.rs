//! Engine-pool integration: the full gateway path (intake → router
//! thread → per-tier queues → continuous-batching replica schedulers)
//! driven by the deterministic synthetic engine — no artifacts or PJRT
//! required, so these run everywhere including CI.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use pick_and_spin::config::Config;
use pick_and_spin::gateway::{serve_http, LiveStack};
use pick_and_spin::testkit::wait_until;

fn pool_config() -> Config {
    let mut cfg = Config::default();
    cfg.pool.replicas = [1, 1, 1];
    cfg.pool.max_inflight = 16;
    cfg.pool.flush_timeout_s = 0.003;
    cfg
}

#[test]
fn concurrent_load_forms_decode_batches() {
    let stack = Arc::new(LiveStack::start_sim(&pool_config()).unwrap());
    // ≥16 in-flight requests against one 16-slot replica per tier: the
    // scheduler must form real decode batches, not serial steps.
    let n = 32u64;
    let handles: Vec<_> = (0..n)
        .map(|i| {
            let s = Arc::clone(&stack);
            std::thread::spawn(move || {
                s.complete(&format!("what is {i} plus {i}?"), 16).unwrap()
            })
        })
        .collect();
    let mut total_tokens = 0usize;
    for h in handles {
        let r = h.join().unwrap();
        assert!(!r.tokens.is_empty());
        assert!(r.latency_s >= r.ttft_s, "latency below TTFT");
        assert!(r.queue_wait_s >= 0.0);
        total_tokens += r.tokens.len();
    }
    let m = &stack.metrics;
    assert_eq!(m.requests.load(Ordering::Relaxed), n);
    assert_eq!(m.completed.load(Ordering::Relaxed), n);
    assert_eq!(m.errors.load(Ordering::Relaxed), 0);
    assert_eq!(m.tokens_out.load(Ordering::Relaxed) as usize, total_tokens);
    // The acceptance signal: decode batches > 1 actually formed.
    assert!(
        m.batched.load(Ordering::Relaxed) > 0,
        "no batched decode steps under 32-way concurrency"
    );
    // The batch histogram saw a multi-sequence rung.
    let multi: u64 = m.batch_counts[1..]
        .iter()
        .map(|c| c.load(Ordering::Relaxed))
        .sum();
    assert!(multi > 0, "batch histogram never left rung 1");
}

#[test]
fn http_gateway_exposes_batching_metrics() {
    use pick_and_spin::gateway::http::http_request;

    let stack = Arc::new(LiveStack::start_sim(&pool_config()).unwrap());
    let srv = serve_http(Arc::clone(&stack), 0, 16).unwrap();
    let port = srv.port;
    let handles: Vec<_> = (0..16)
        .map(|i| {
            std::thread::spawn(move || {
                http_request(
                    port,
                    "POST",
                    "/v1/completions",
                    Some(&format!(
                        r#"{{"prompt": "compute {i} plus {i}", "max_tokens": 12}}"#
                    )),
                )
                .unwrap()
            })
        })
        .collect();
    for h in handles {
        let (status, body) = h.join().unwrap();
        assert_eq!(status, 200, "body: {body}");
        let j = pick_and_spin::util::json::Json::parse(&body).unwrap();
        assert!(j.rarr("tokens").unwrap().len() <= 12);
    }
    let (status, metrics) = http_request(port, "GET", "/metrics", None).unwrap();
    assert_eq!(status, 200);
    assert!(metrics.contains("ps_completed_total 16"), "{metrics}");
    assert!(metrics.contains("ps_queue_wait_seconds_total"));
    assert!(metrics.contains("ps_decode_b8_total"));
    let batched: f64 = metrics
        .lines()
        .find(|l| l.starts_with("ps_batched_total "))
        .and_then(|l| l.split_whitespace().nth(1))
        .expect("ps_batched_total missing")
        .parse()
        .unwrap();
    assert!(batched > 0.0, "batching did not engage:\n{metrics}");
    srv.stop();
}

#[test]
fn idle_tiers_scale_to_zero_and_cold_wake_on_demand() {
    let mut cfg = pool_config();
    cfg.orchestrator.idle_timeout_s = 0.2;
    cfg.orchestrator.warm_pool = [1, 0, 0];
    cfg.pool.scale_interval_s = 0.05;
    let stack = LiveStack::start_sim(&cfg).unwrap();
    assert_eq!(stack.active_replicas(), 3);

    stack.complete("what is 2 plus 2?", 4).unwrap();
    // Queue depth + slot occupancy hit zero, idle clock runs → the
    // scaler parks every tier down to its warm floor (bounded poll on
    // the replica count, not a fixed sleep).
    assert!(
        wait_until(Duration::from_secs(10), || stack.active_replicas() <= 1),
        "idle tiers must park to the warm-pool floor (have {})",
        stack.active_replicas()
    );
    assert_eq!(stack.active_replicas(), 1, "the warm floor itself stays");

    // A hard prompt routes to a parked tier → cold wake, still served.
    let r = stack
        .complete("prove that the sum converges and derive a closed form", 6)
        .unwrap();
    assert!(!r.tokens.is_empty());
    assert!(r.complexity >= 1, "proof prompt misclassified");
    assert!(
        stack.metrics.cold_wakes.load(Ordering::Relaxed) >= 1,
        "serving a parked tier must count a cold wake"
    );
}

#[test]
fn impossible_requests_fail_fast_instead_of_wedging_the_replica() {
    let mut cfg = pool_config();
    // A tiny KV pool (4 blocks × 4 tokens): a 16-token budget can never
    // fit, so the gateway must reply with an admission error instead of
    // bouncing the job forever (which wedged the replica and hung
    // shutdown before the fix).
    cfg.pool.kv_blocks = 4;
    cfg.pool.kv_block_tokens = 4;
    let stack = LiveStack::start_sim(&cfg).unwrap();
    let err = stack
        .complete("what is 2 plus 2?", 16)
        .expect_err("an unserveable request must error, not hang");
    assert!(
        format!("{err:#}").contains("admission failed"),
        "unexpected error: {err:#}"
    );
    // The replica stayed healthy: a request that fits still serves.
    let r = stack.complete("what is 2 plus 2?", 4).unwrap();
    assert_eq!(r.tokens.len(), 4);
    // Dropping the stack must join cleanly (no wedged replica thread).
    drop(stack);
}

#[test]
fn timed_out_requests_cancel_mid_flight_and_free_their_slot() {
    let mut cfg = pool_config();
    // A 256-token decode on the calibrated sim engine takes ~50 ms; a
    // 5 ms request timeout must cancel it mid-flight instead of letting
    // it decode to completion.
    cfg.gateway.request_timeout_s = 0.005;
    let stack = LiveStack::start_sim(&cfg).unwrap();
    let err = stack
        .complete("please summarize everything about alpha beta gamma", 256)
        .expect_err("a 5ms timeout cannot cover a 50ms decode");
    assert!(format!("{err:#}").contains("timed out"), "{err:#}");
    assert_eq!(stack.metrics.timeouts.load(Ordering::Relaxed), 1);
    // The sequence is evicted at the scheduler's next tick, freeing the
    // slot and KV reservation early.
    assert!(
        wait_until(Duration::from_secs(5), || {
            stack.metrics.cancelled.load(Ordering::Relaxed) >= 1
                && stack.slots_in_use() == 0
        }),
        "timeout must cancel the in-flight sequence and free its slot \
         (cancelled={}, slots={})",
        stack.metrics.cancelled.load(Ordering::Relaxed),
        stack.slots_in_use()
    );
}

#[test]
fn graceful_drain_requeues_queued_jobs_loss_free() {
    // Terminate the only small-tier replica while it holds admitted and
    // queued work: the buffered jobs must route back through the requeue
    // path (not be dropped with the replica), the orphan guard must cold
    // wake a replacement, and every caller must still get its answer —
    // loss-free scale-down.
    let mut cfg = pool_config();
    cfg.pool.replicas = [1, 1, 1];
    cfg.pool.max_inflight = 4;
    cfg.pool.max_prefill_batch = 1;
    cfg.pool.scale_interval_s = 0.05;
    cfg.orchestrator.idle_timeout_s = 3600.0;
    let stack = Arc::new(LiveStack::start_sim(&cfg).unwrap());
    let n = 12u64;
    let handles: Vec<_> = (0..n)
        .map(|i| {
            let s = Arc::clone(&stack);
            std::thread::spawn(move || s.complete(&format!("what is {i} plus {i}?"), 48))
        })
        .collect();
    // Drain the replica mid-flight — once decode slots are actually
    // occupied (bounded poll on the occupancy cells; the fixed 5 ms
    // sleep this replaces missed the window under a loaded scheduler).
    assert!(
        wait_until(Duration::from_secs(10), || stack.slots_in_use() > 0),
        "replica never started decoding"
    );
    assert!(
        stack.drain_replica(0),
        "no Ready small-tier replica to drain"
    );
    for h in handles {
        let r = h
            .join()
            .unwrap()
            .expect("request lost across a graceful drain");
        assert!(!r.tokens.is_empty());
    }
    let m = &stack.metrics;
    assert_eq!(m.completed.load(Ordering::Relaxed), n);
    assert_eq!(m.errors.load(Ordering::Relaxed), 0, "drain must not error jobs");
    assert!(
        m.requeued.load(Ordering::Relaxed) >= 1,
        "draining replica must hand queued work back through the requeue path"
    );
}

#[test]
fn backpressure_rejects_cleanly_when_tier_queue_full() {
    let mut cfg = pool_config();
    // One slot, one-deep queue, serial batches: the third-plus
    // concurrent request must bounce with the backpressure error.
    cfg.pool.replicas = [1, 1, 1];
    cfg.pool.max_inflight = 1;
    cfg.pool.max_decode_batch = 1;
    cfg.pool.queue_capacity = 1;
    let stack = Arc::new(LiveStack::start_sim(&cfg).unwrap());
    let n = 24;
    let handles: Vec<_> = (0..n)
        .map(|i| {
            let s = Arc::clone(&stack);
            std::thread::spawn(move || s.complete(&format!("what is {i} plus 1?"), 24))
        })
        .collect();
    let mut ok = 0;
    let mut rejected = 0;
    for h in handles {
        match h.join().unwrap() {
            Ok(_) => ok += 1,
            Err(e) => {
                assert!(
                    format!("{e:#}").contains("backpressure"),
                    "unexpected error: {e:#}"
                );
                rejected += 1;
            }
        }
    }
    assert_eq!(ok + rejected, n);
    assert!(ok >= 1, "some requests must still complete");
    let m = &stack.metrics;
    assert_eq!(m.rejected.load(Ordering::Relaxed), rejected as u64);
}