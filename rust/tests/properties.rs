//! Property-based tests (own testkit — proptest is unavailable offline)
//! over coordinator invariants: routing, batching, KV accounting, scaling.

use pick_and_spin::backend::batcher::{BatchPolicy, DECODE_BATCHES};
use pick_and_spin::backend::kv_cache::{KvBlockManager, PrefixCacheConfig, SeqId};
use pick_and_spin::models::BackendKind;
use pick_and_spin::router::keyword::KeywordRouter;
use pick_and_spin::substrate::proto::{Frame, FrameReader, MAX_FRAME_BYTES};
use pick_and_spin::testkit::{check, Gen};
use pick_and_spin::tokenizer;
use pick_and_spin::util::json::Json;
use pick_and_spin::util::stats::{percentile, Summary};

#[test]
fn prop_tokenizer_well_formed_for_any_text() {
    check("tokenizer well-formed", 300, |g: &mut Gen| {
        let text = g.text(60);
        let ids = tokenizer::encode(&text, tokenizer::SEQ_CLS);
        assert_eq!(ids.len(), tokenizer::SEQ_CLS);
        assert_eq!(ids[0], tokenizer::CLS as i32);
        let n = tokenizer::valid_len(&ids);
        assert!(ids[..n].iter().all(|&i| i != tokenizer::PAD as i32));
        assert!(ids[n..].iter().all(|&i| i == tokenizer::PAD as i32));
        assert!(ids.iter().all(|&i| (0..tokenizer::VOCAB as i32).contains(&i)));
    });
}

#[test]
fn prop_keyword_router_total_and_bounded() {
    check("keyword router total", 500, |g: &mut Gen| {
        let text = g.text(50);
        let c = KeywordRouter::classify(&text);
        assert!(c.complexity <= 2);
        assert!((0.0..=1.0).contains(&c.confidence));
        assert_eq!(c.overhead_s, 0.0);
        // Determinism
        let c2 = KeywordRouter::classify(&text);
        assert_eq!(c.complexity, c2.complexity);
    });
}

#[test]
fn prop_kv_manager_never_leaks_blocks() {
    check("kv conservation", 100, |g: &mut Gen| {
        let total = g.usize(4..64);
        let block = g.usize(1..32);
        let mut kv = KvBlockManager::new(total, block);
        let mut live: Vec<SeqId> = Vec::new();
        for i in 0..200u64 {
            if g.bool() {
                let prompt = g.usize(1..40);
                let gen_budget = g.usize(0..40);
                if kv.blocks_for_tokens(prompt + gen_budget) <= kv.available_blocks() {
                    kv.admit(SeqId(i), prompt, gen_budget).unwrap();
                    live.push(SeqId(i));
                }
            } else if !live.is_empty() {
                let idx = g.usize(0..live.len());
                kv.release(live.swap_remove(idx));
            }
            kv.check_invariants().unwrap();
        }
        for id in live {
            kv.release(id);
        }
        assert_eq!(kv.free_blocks(), total);
    });
}

#[test]
fn prop_prefix_cache_refcounts_conserve_blocks() {
    check("prefix cache conservation", 60, |g: &mut Gen| {
        let block = g.usize(1..8);
        let total = g.usize(8..64);
        let cfg = PrefixCacheConfig {
            enabled: true,
            min_block_run: g.usize(1..3),
            evict_watermark: g.f64(0.3..1.0),
        };
        let mut kv = KvBlockManager::with_prefix_cache(total, block, cfg);
        // Shared-prefix families: admissions fork off these bases at a
        // random depth — the admit/fork/release/evict interleaving the
        // radix tree must survive.
        let bases: Vec<Vec<i32>> = (0..3)
            .map(|b| (0..4 * block as i32).map(|i| b * 1000 + i).collect())
            .collect();
        let mut live: Vec<SeqId> = Vec::new();
        for i in 0..250u64 {
            if g.bool() {
                let base = &bases[g.usize(0..bases.len())];
                let cut = g.usize(0..base.len() + 1);
                let mut ids: Vec<i32> = base[..cut].to_vec();
                for _ in 0..g.usize(0..2 * block) {
                    ids.push(5000 + g.usize(0..50) as i32);
                }
                let max_new = g.usize(1..3 * block);
                // The pre-check is optimistic (pinning a matched chain
                // can shrink what is actually evictable), so a failed
                // admit is legal — it must just roll back cleanly.
                if kv.probe(&ids, max_new).admissible
                    && kv.admit_prefix(SeqId(i), &ids, max_new).is_ok()
                {
                    live.push(SeqId(i));
                }
            } else if !live.is_empty() {
                let idx = g.usize(0..live.len());
                kv.release(live.swap_remove(idx));
            }
            kv.check_invariants().unwrap();
        }
        for id in live {
            kv.release(id);
        }
        kv.check_invariants().unwrap();
        kv.purge_cache();
        assert_eq!(kv.free_blocks(), total, "all blocks recovered");
    });
}

#[test]
fn prop_batcher_returns_compiled_sizes_only() {
    check("batcher ladder", 300, |g: &mut Gen| {
        let kind = *g.pick(&BackendKind::ALL);
        let policy = BatchPolicy::for_backend(kind);
        let waiting = g.usize(0..40);
        let timed_out = g.bool();
        if let Some(b) = policy.decode_batch_size(waiting, timed_out) {
            assert!(DECODE_BATCHES.contains(&b));
            assert!(b <= waiting);
            assert!(b <= policy.max_decode_batch);
        } else {
            // Refusing to batch is only allowed when not timed out or empty.
            assert!(waiting == 0 || !timed_out);
        }
    });
}

#[test]
fn prop_json_roundtrip_preserves_structure() {
    check("json roundtrip", 150, |g: &mut Gen| {
        // Build a random JSON value.
        fn build(g: &mut Gen, depth: usize) -> Json {
            match if depth > 2 { g.usize(0..4) } else { g.usize(0..6) } {
                0 => Json::Null,
                1 => Json::Bool(g.bool()),
                2 => Json::Num((g.f64(-1e6..1e6) * 100.0).round() / 100.0),
                3 => Json::str(g.text(6)),
                4 => Json::arr((0..g.usize(0..4)).map(|_| build(g, depth + 1))),
                _ => Json::obj(
                    (0..g.usize(0..4))
                        .map(|i| {
                            (["a", "b", "c", "d"][i], build(g, depth + 1))
                        })
                        .collect(),
                ),
            }
        }
        let v = build(g, 0);
        assert_eq!(Json::parse(&v.dump()).unwrap(), v);
        assert_eq!(Json::parse(&v.pretty()).unwrap(), v);
    });
}

#[test]
fn prop_json_strings_roundtrip_hostile_text() {
    // RPC frames carry user prompt text: control characters, quote/
    // backslash runs, BMP and non-BMP (astral) code points must all
    // survive dump → parse bit-for-bit — a lossy escape corrupts jobs on
    // the wire.
    check("json hostile string roundtrip", 300, |g: &mut Gen| {
        let mut s = String::new();
        for _ in 0..g.usize(0..40) {
            let c = match g.usize(0..6) {
                // C0 control characters (incl. \n \r \t \b \f at 10/13/9/8/12)
                0 => char::from_u32(g.u32(0..0x20)).unwrap(),
                // Quote, backslash, solidus
                1 => *g.pick(&['"', '\\', '/']),
                // Plain ASCII
                2 | 3 => char::from_u32(g.u32(0x20..0x7f)).unwrap(),
                // BMP beyond ASCII (skip the surrogate range)
                4 => char::from_u32(g.u32(0xA0..0xD7FF)).unwrap(),
                // Non-BMP: emoji / CJK extension (surrogate pairs in the
                // escaped form, 4-byte UTF-8 raw)
                _ => char::from_u32(g.u32(0x1_F300..0x1_FA00)).unwrap(),
            };
            s.push(c);
        }
        let v = Json::Str(s.clone());
        let dumped = v.dump();
        assert!(
            dumped.bytes().all(|b| b >= 0x20),
            "escaped output must contain no raw control bytes: {dumped:?}"
        );
        let back = Json::parse(&dumped).unwrap();
        assert_eq!(back.as_str().unwrap(), s, "string mangled in roundtrip");
        // Nested inside an object as both key and value.
        let obj = Json::obj(vec![("prompt", Json::str(s.clone()))]);
        assert_eq!(Json::parse(&obj.dump()).unwrap(), obj);
        assert_eq!(Json::parse(&obj.pretty()).unwrap(), obj);
    });
}

/// A chain hash stressing the full u64 range — including values above
/// f64's 2^53 exact-integer ceiling, which is why hashes cross the wire
/// as hex strings rather than JSON numbers.
fn arb_hash(g: &mut Gen) -> u64 {
    match g.usize(0..4) {
        0 => u64::MAX,
        1 => (1u64 << 53) + 1,
        _ => g.u64(0..u64::MAX),
    }
}

/// One random wire frame (the kinds that carry variable payloads).
fn arb_frame(g: &mut Gen) -> Frame {
    match g.usize(0..9) {
        0 => Frame::Ping { nonce: g.u64(0..1_000_000) },
        1 => Frame::Job {
            job: g.u64(0..1000),
            prompt: g.text(20),
            max_tokens: g.usize(1..64),
        },
        2 => Frame::TokenChunk {
            job: g.u64(0..1000),
            tokens: g.vec(0..8, |g| g.u32(0..50_000) as i32),
        },
        3 => Frame::Cancelled { job: g.u64(0..1000) },
        4 => Frame::Returned { job: g.u64(0..1000) },
        5 => Frame::PrefixAd {
            prefixes: g.vec(0..4, |g| (arb_hash(g), g.u32(1..64))),
        },
        6 => Frame::FetchBlocks { req: g.u64(1..1000), hash: arb_hash(g) },
        7 => Frame::BlocksChunk {
            req: g.u64(0..1000),
            hash: arb_hash(g),
            blocks: g.vec(0..3, |g| g.vec(0..5, |g| g.u32(0..50_000) as i32)),
            done: g.bool(),
        },
        _ => Frame::Gone,
    }
}

#[test]
fn prop_frame_reader_decodes_any_fragmentation() {
    // The RPC plane's framing invariant: however a valid frame stream is
    // fragmented or coalesced by the transport (seeded adversarial chunk
    // sizes), the decoded sequence is identical — and a stream severed
    // mid-frame stays cleanly pending (`Ok(None)`), never a panic, a
    // desync error, or a phantom frame.
    check("frame fragmentation", 200, |g: &mut Gen| {
        let frames: Vec<Frame> = g.vec(1..8, arb_frame);
        let encoded: Vec<Vec<u8>> = frames.iter().map(|f| f.encode()).collect();
        let stream: Vec<u8> = encoded.iter().flatten().copied().collect();
        // Sever point: anywhere in the stream (== len means no cut).
        let cut = g.usize(0..stream.len() + 1);
        // Frames fully contained before the sever must decode; the one
        // the cut lands inside must not.
        let mut expected = Vec::new();
        let mut off = 0usize;
        for (f, e) in frames.iter().zip(&encoded) {
            off += e.len();
            if off <= cut {
                expected.push(f.clone());
            } else {
                break;
            }
        }
        let mut r = FrameReader::new();
        let mut got = Vec::new();
        let mut i = 0usize;
        while i < cut {
            let n = g.usize(1..65).min(cut - i);
            r.extend(&stream[i..i + n]);
            i += n;
            while let Some(f) = r.next().expect("valid stream never desyncs") {
                got.push(f);
            }
        }
        assert_eq!(got, expected, "fragmentation changed the decoded sequence");
        assert!(
            r.next().expect("severed tail must not error").is_none(),
            "a mid-frame sever must leave the reader pending, not yield a frame"
        );
    });
}

/// Read a chaos endpoint dry (zero timeout → `WouldBlock` when idle,
/// `Ok(0)` on sever), decoding through a caller-held reader so partial
/// frames persist across calls.
fn drain_chaos(
    end: &mut pick_and_spin::testkit::chaos::ChaosEnd,
    reader: &mut FrameReader,
) -> (Vec<Frame>, bool) {
    use pick_and_spin::substrate::proto::Transport;
    end.set_read_timeout(Some(std::time::Duration::ZERO)).unwrap();
    let mut out = Vec::new();
    let mut buf = [0u8; 96];
    loop {
        match end.read(&mut buf) {
            Ok(0) => return (out, true),
            Ok(n) => {
                reader.extend(&buf[..n]);
                while let Some(f) = reader.next().expect("valid stream never desyncs") {
                    out.push(f);
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                return (out, false);
            }
            Err(e) => panic!("chaos read failed: {e}"),
        }
    }
}

#[test]
fn prop_kv_transfer_frames_survive_chaos_transport() {
    // The fetch/chunk exchange that moves KV blocks between replicas,
    // run over the fault-injecting transport: whatever fragment
    // boundaries the seed picks, the reassembled run is bit-identical
    // to what the donor exported — and a link severed mid-transfer ends
    // in a clean EOF with `done` never observed, so the recipient
    // imports nothing rather than a truncated prefix.
    use pick_and_spin::substrate::proto::write_frame;
    use pick_and_spin::testkit::chaos;

    check("kv transfer over chaos", 80, |g: &mut Gen| {
        let hash = arb_hash(g);
        let run: Vec<Vec<i32>> =
            g.vec(1..6, |g| g.vec(1..8, |g| g.u32(0..50_000) as i32));

        // Clean transfer: supervisor fetches, donor answers block by
        // block with `done` on the last chunk.
        let (mut sup, mut wrk) = chaos::pair(g.u64(0..u64::MAX));
        write_frame(&mut sup, &Frame::FetchBlocks { req: 7, hash }).unwrap();
        let mut wrk_reader = FrameReader::new();
        let (got, eof) = drain_chaos(&mut wrk, &mut wrk_reader);
        assert!(!eof);
        assert_eq!(got, vec![Frame::FetchBlocks { req: 7, hash }]);
        for (i, b) in run.iter().enumerate() {
            write_frame(&mut wrk, &Frame::BlocksChunk {
                req: 7,
                hash,
                blocks: vec![b.clone()],
                done: i + 1 == run.len(),
            })
            .unwrap();
        }
        let mut sup_reader = FrameReader::new();
        let (chunks, _) = drain_chaos(&mut sup, &mut sup_reader);
        let mut rebuilt: Vec<Vec<i32>> = Vec::new();
        let mut done = false;
        for f in chunks {
            match f {
                Frame::BlocksChunk { req: 7, hash: h, blocks, done: d } => {
                    assert_eq!(h, hash, "chunk answered with the wrong hash");
                    assert!(!done, "chunks after done");
                    rebuilt.extend(blocks);
                    done = d;
                }
                f => panic!("unexpected frame {f:?}"),
            }
        }
        assert!(done, "transfer must terminate with done");
        assert_eq!(rebuilt, run, "reassembled run must match the export");

        // Severed mid-transfer: the tail chunk is held in flight and the
        // link cut — the receiver sees every fully delivered chunk, then
        // EOF; `done` never arrives, so nothing gets imported.
        let (mut sup, mut wrk) = chaos::pair(g.u64(0..u64::MAX));
        write_frame(&mut wrk, &Frame::BlocksChunk {
            req: 9,
            hash,
            blocks: run[..run.len() - 1].to_vec(),
            done: false,
        })
        .unwrap();
        wrk.hold();
        write_frame(&mut wrk, &Frame::BlocksChunk {
            req: 9,
            hash,
            blocks: vec![run[run.len() - 1].clone()],
            done: true,
        })
        .unwrap();
        wrk.sever();
        let mut reader = FrameReader::new();
        let mut partial: Vec<Vec<i32>> = Vec::new();
        let mut saw_done = false;
        loop {
            let (fs, eof) = drain_chaos(&mut sup, &mut reader);
            for f in fs {
                match f {
                    Frame::BlocksChunk { blocks, done, .. } => {
                        partial.extend(blocks);
                        saw_done |= done;
                    }
                    f => panic!("unexpected frame {f:?}"),
                }
            }
            if eof {
                break;
            }
        }
        assert!(!saw_done, "a severed transfer must never look complete");
        assert_eq!(
            partial,
            run[..run.len() - 1].to_vec(),
            "delivered chunks must still decode exactly"
        );
        assert!(
            reader.next().expect("severed tail must not error").is_none(),
            "mid-frame sever leaves the reader pending, never a phantom frame"
        );
    });
}

#[test]
fn frame_guard_boundary_cases() {
    // len == guard: a frame that fills MAX_FRAME_BYTES exactly is legal
    // — pending while partial, decoded once complete.
    let probe = Frame::Job { job: 1, prompt: String::new(), max_tokens: 1 }.encode();
    let overhead = probe.len() - 4; // body bytes with an empty prompt
    let pad = MAX_FRAME_BYTES - overhead;
    let big = Frame::Job { job: 1, prompt: "a".repeat(pad), max_tokens: 1 }.encode();
    assert_eq!(
        big.len(),
        4 + MAX_FRAME_BYTES,
        "constructed frame must fill the guard exactly"
    );
    let mut r = FrameReader::new();
    r.extend(&big[..big.len() - 1]);
    assert!(
        r.next().unwrap().is_none(),
        "guard-size frame mid-arrival is pending, not an error"
    );
    r.extend(&big[big.len() - 1..]);
    match r.next().unwrap().expect("guard-size frame must decode") {
        Frame::Job { prompt, .. } => assert_eq!(prompt.len(), pad),
        f => panic!("wrong frame {f:?}"),
    }
    assert!(r.next().unwrap().is_none(), "no trailing bytes");

    // len == guard + 1: rejected from the length prefix alone — a
    // garbled prefix must never trigger the allocation.
    let mut r = FrameReader::new();
    r.extend(&(MAX_FRAME_BYTES as u32 + 1).to_be_bytes());
    assert!(r.next().is_err(), "guard+1 must be rejected");
}

#[test]
fn prop_percentiles_monotone_and_bounded() {
    check("percentile order", 200, |g: &mut Gen| {
        let xs = g.vec(1..200, |g| g.f64(-1e3..1e3));
        let s = Summary::of(&xs);
        assert!(s.min <= s.p50 && s.p50 <= s.p95 && s.p95 <= s.p99);
        assert!(s.p99 <= s.max);
        let p0 = percentile(&xs, 0.0);
        let p100 = percentile(&xs, 100.0);
        assert!((p0 - s.min).abs() < 1e-9);
        assert!((p100 - s.max).abs() < 1e-9);
    });
}

#[test]
fn prop_scaling_targets_littles_law() {
    use pick_and_spin::config::OrchestratorConfig;
    use pick_and_spin::models::zoo;
    use pick_and_spin::orchestrator::{ScaleAction, Scaler};
    use pick_and_spin::registry::{Registry, ServiceId};

    check("littles law", 60, |g: &mut Gen| {
        let rate = g.f64(0.5..10.0);
        let lat = g.f64(1.0..20.0);
        let conc = g.f64(2.0..16.0);
        let mut registry = Registry::new(&zoo(), 300.0);
        let cfg = OrchestratorConfig {
            target_concurrency: conc,
            max_replicas: 1000,
            warm_pool: [0, 0, 0],
            ..OrchestratorConfig::default()
        };
        let mut scaler = Scaler::new(cfg, registry.services.len());
        // Drive synthetic telemetry into service 0.
        {
            let svc = registry.get_mut(ServiceId(0));
            let n = (rate * 300.0) as usize;
            for i in 0..n {
                let t = i as f64 / rate;
                svc.telemetry.on_dispatch(t, 1e9);
                svc.telemetry.on_complete(t + lat, 1e9, lat, 0.1, true);
            }
        }
        let expected = (rate * lat / conc).ceil() as usize;
        let actions = scaler.plan(&mut registry, 300.0);
        match actions.iter().find(|a| matches!(a,
            ScaleAction::Up { service: ServiceId(0), .. })) {
            Some(ScaleAction::Up { target, .. }) => {
                // EMA-smoothed latency and window-edge effects allow ±40%.
                let lo = (expected as f64 * 0.6) as usize;
                let hi = (expected as f64 * 1.5).ceil() as usize + 1;
                assert!((lo..=hi).contains(target),
                        "target {target} for expected {expected}");
            }
            _ => assert!(expected == 0,
                         "no scale-up planned but expected {expected}"),
        }
    });
}
