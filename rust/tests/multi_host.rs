//! Multi-host substrate integration: the process substrate placing
//! replicas across two real `ps-node` agents on localhost TCP. Each
//! agent is a separate OS process (spawned from `CARGO_BIN_EXE`), each
//! worker another one dialing the supervisor's per-replica TCP listener
//! — the full paper deployment shape, one machine standing in for many.
//! Covers: registration → placement spread (asserted at the registry and
//! through the `/metrics` per-node gauges), the substrate conformance
//! suite (base + node cases) over TCP, and the headline incident —
//! SIGKILL of an entire node-agent mid-decode, which must fail every
//! hosted replica together, requeue their dispatch ledgers loss-free
//! (`ps_requeued_total > 0`, zero lost completions), and re-provision
//! the fleet on the surviving node.

use std::net::TcpListener;
use std::process::{Child, Command, Stdio};
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use pick_and_spin::config::{Config, SubstrateKind};
use pick_and_spin::gateway::LiveStack;
use pick_and_spin::models::zoo;
use pick_and_spin::registry::Registry;
use pick_and_spin::substrate::remote::{ProcessSubstrate, WorkerSpec};
use pick_and_spin::testkit::substrate_conformance::{
    check, check_nodes, Driver, NodeDriver,
};
use pick_and_spin::testkit::wait_until;

const BIN: &str = env!("CARGO_BIN_EXE_pick-and-spin");

/// Reserve a free localhost port (bind to 0, note, release). The brief
/// release window is benign on a CI runner: the agent rebinds within
/// milliseconds and the supervisor dials with a 10 s retry.
fn free_port() -> u16 {
    TcpListener::bind("127.0.0.1:0")
        .unwrap()
        .local_addr()
        .unwrap()
        .port()
}

struct Agent {
    name: String,
    addr: String,
    child: Child,
}

impl Drop for Agent {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

fn spawn_agent(name: &str, slots: usize) -> Agent {
    let addr = format!("127.0.0.1:{}", free_port());
    let mut cmd = Command::new(BIN);
    cmd.arg("ps-node")
        .arg("--listen")
        .arg(&addr)
        .arg("--slots")
        .arg(slots.to_string())
        .arg("--name")
        .arg(name)
        .stdin(Stdio::null())
        .stdout(Stdio::null());
    if let Ok(dir) = std::env::var("PS_WORKER_LOG_DIR") {
        cmd.arg("--log-dir").arg(dir);
    }
    let child = cmd.spawn().expect("spawn ps-node agent");
    Agent { name: name.to_string(), addr, child }
}

fn node_config(agents: &[&Agent]) -> Config {
    let mut cfg = Config::default();
    cfg.pool.substrate = SubstrateKind::Process;
    cfg.pool.worker_bin = Some(BIN.to_string());
    cfg.pool.worker_log_dir = std::env::var("PS_WORKER_LOG_DIR").ok();
    cfg.pool.nodes.agents = agents.iter().map(|a| a.addr.clone()).collect();
    cfg
}

fn metric(stack: &LiveStack, name: &str) -> f64 {
    stack
        .metrics_snapshot()
        .into_iter()
        .find(|(n, _)| n == name)
        .map(|(_, v)| v)
        .unwrap_or_else(|| panic!("{name} missing from /metrics"))
}

#[test]
fn tcp_substrate_with_two_node_agents_passes_conformance() {
    // The same lifecycle contract Mock/Local/Process(Unix) run, now with
    // every worker spawned by a node agent and speaking TCP — plus the
    // node-level cases: placement spread, node loss failing exactly the
    // hosted replica, re-provision on the survivor. The sever is a real
    // SIGKILL of the whole agent process.
    let a0 = spawn_agent("n0", 4);
    let a1 = spawn_agent("n1", 4);
    let z = zoo();
    let registry = Registry::new(&z, 300.0);
    let mut cfg = node_config(&[&a0, &a1]);
    cfg.pool.replicas = [2, 2, 2];
    let spec = WorkerSpec::from_pool(&cfg.pool, &["--engine", "sim"]).unwrap();
    let mut sub = ProcessSubstrate::standalone(cfg.pool.clone(), &registry, spec);
    let reg = sub.nodes().expect("node plane must be up");
    let epoch = sub.epoch();
    let sid = sub.tier_service(0);
    let (mspec, backend) = {
        let s = registry.get(sid);
        (s.spec.clone(), s.backend)
    };
    let agents = Arc::new(Mutex::new(vec![a0, a1]));
    {
        let base = Driver {
            substrate: &mut sub,
            service: sid,
            model_idx: 0,
            spec: mspec,
            backend,
            clock: Box::new(move || {
                std::thread::sleep(Duration::from_millis(5));
                epoch.elapsed().as_secs_f64()
            }),
            timeout_s: 30.0,
        };
        let reg_hosted = Arc::clone(&reg);
        let reg_alive = Arc::clone(&reg);
        let agents_sever = Arc::clone(&agents);
        let mut d = NodeDriver {
            base,
            node_names: vec!["n0".into(), "n1".into()],
            hosted_on: Box::new(move |n| {
                reg_hosted
                    .snapshot()
                    .iter()
                    .find(|s| s.name == n)
                    .map(|s| s.hosted)
                    .unwrap_or(0)
            }),
            alive: Box::new(move |n| {
                reg_alive.snapshot().iter().any(|s| s.name == n && s.alive)
            }),
            sever: Box::new(move |n| {
                for a in agents_sever.lock().unwrap().iter_mut() {
                    if a.name == n {
                        let _ = a.child.kill();
                    }
                }
            }),
        };
        // Base contract first (lifecycle, fail→event, terminate during
        // Loading — all over TCP through an agent), then the node cases.
        check(&mut d.base);
        check_nodes(&mut d);
    }
    sub.shutdown();
}

#[test]
fn node_agent_sigkill_mid_decode_recovers_loss_free() {
    // The acceptance scenario: a whole node dies (agent SIGKILLed) while
    // its replicas are decoding. Every hosted replica must fail together,
    // their dispatch ledgers requeue loss-free, the scaler re-provisions
    // on the surviving node, and every caller still gets its answer.
    let mut a0 = spawn_agent("n0", 8);
    let a1 = spawn_agent("n1", 8);
    let mut cfg = node_config(&[&a0, &a1]);
    cfg.pool.replicas = [2, 1, 1];
    cfg.pool.max_inflight = 8;
    cfg.pool.flush_timeout_s = 0.003;
    cfg.pool.scale_interval_s = 0.05;
    cfg.orchestrator.idle_timeout_s = 3600.0;
    let stack = Arc::new(LiveStack::start_sim(&cfg).unwrap());
    assert_eq!(stack.active_replicas(), 4);

    // Spread placement, proven through the per-node /metrics gauges:
    // [2,1,1] replicas across two empty nodes must split 2/2.
    assert_eq!(metric(&stack, "ps_node_replicas{node=\"n0\"}"), 2.0);
    assert_eq!(metric(&stack, "ps_node_replicas{node=\"n1\"}"), 2.0);
    assert_eq!(metric(&stack, "ps_node_capacity{node=\"n0\"}"), 8.0);
    assert_eq!(metric(&stack, "ps_node_up{node=\"n0\"}"), 1.0);
    assert_eq!(metric(&stack, "ps_node_lost_total"), 0.0);

    let n = 48u64;
    let handles: Vec<_> = (0..n)
        .map(|i| {
            let s = Arc::clone(&stack);
            std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(i * 2));
                s.complete(&format!("what is {i} plus {i}?"), 24)
            })
        })
        .collect();

    // SIGKILL the whole n0 agent once decode is actually in flight
    // (bounded poll on slot occupancy — no fixed sleep).
    assert!(
        wait_until(Duration::from_secs(10), || stack.slots_in_use() > 0),
        "traffic never started decoding"
    );
    let _ = a0.child.kill();

    // Zero lost completions across the node death.
    for h in handles {
        let r = h
            .join()
            .unwrap()
            .expect("request lost across a node-agent SIGKILL");
        assert!(!r.tokens.is_empty());
    }

    // The node read as lost, and the fleet re-provisioned on n1.
    assert!(
        wait_until(Duration::from_secs(20), || {
            metric(&stack, "ps_node_lost_total") >= 1.0
                && stack.active_replicas() == 4
        }),
        "node loss never recovered: lost={} replicas={}",
        metric(&stack, "ps_node_lost_total"),
        stack.active_replicas()
    );
    assert_eq!(metric(&stack, "ps_node_up{node=\"n0\"}"), 0.0);
    assert!(
        wait_until(Duration::from_secs(10), || {
            metric(&stack, "ps_node_replicas{node=\"n1\"}") >= 4.0
        }),
        "replacements must land on the surviving node"
    );
    assert_eq!(metric(&stack, "ps_node_replicas{node=\"n0\"}"), 0.0);
    assert!(
        stack.metrics.requeued.load(Ordering::Relaxed) >= 1,
        "in-flight jobs must requeue off the lost node's ledgers"
    );
    assert!(metric(&stack, "ps_incidents_total") >= 2.0, "both hosted replicas fail");
    assert_eq!(stack.metrics.errors.load(Ordering::Relaxed), 0);
    assert_eq!(stack.metrics.completed.load(Ordering::Relaxed), n);
    drop(stack);
    drop(a1);
}
