//! Fleet-wide prefix cache integration: cache-affinity routing
//! (replicas advertise hot prefix summaries, the router scores each
//! request's chain hashes against them and direct-places on the longest
//! match) and cross-replica KV block transfer (a saturated hot replica
//! spills to a cold peer with a brokered copy of the shared prefix).
//! Also covers the redesigned dispatch entry API: `CompletionRequest`
//! builder, per-request deadlines, and the HTTP fields they parse from.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use pick_and_spin::config::{Config, SubstrateKind};
use pick_and_spin::gateway::{CompletionRequest, LiveStack};

/// 16 words — four 4-token blocks under `kv_block_tokens = 4`, so every
/// request sharing it produces the same leading chain hashes.
const PREAMBLE: &str = "alpha beta gamma delta epsilon zeta eta theta \
                        iota kappa lambda mu nu xi omicron pi";

fn acfg() -> Config {
    let mut cfg = Config::default();
    cfg.pool.replicas = [2, 1, 1];
    cfg.pool.max_inflight = 4;
    cfg.pool.flush_timeout_s = 0.003;
    cfg.pool.kv_block_tokens = 4;
    cfg.pool.affinity.enabled = true;
    cfg
}

#[test]
fn shared_prefix_requests_converge_on_the_cached_replica() {
    let stack = LiveStack::start_sim(&acfg()).unwrap();
    let m = &stack.metrics;
    // The first request lands through the legacy tier queue (no replica
    // has advertised anything yet) and counts as a fallback; repeats
    // re-send until the serving replica's hot-prefix ad propagates and
    // the router scores a match.
    let mut hits = 0u64;
    for i in 0..40 {
        let r = stack.complete(&format!("{PREAMBLE} question {i}"), 4).unwrap();
        assert!(!r.tokens.is_empty());
        hits = m.affinity_hits.load(Ordering::Relaxed);
        if hits > 0 {
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    assert!(hits > 0, "router never scored an affinity hit");
    assert!(
        m.affinity_match_blocks.load(Ordering::Relaxed) >= hits,
        "every hit matches at least one block"
    );
    // The dispatch invariant: with affinity on, every routed request
    // counts exactly one of hit / fallback.
    assert_eq!(
        hits + m.affinity_fallbacks.load(Ordering::Relaxed),
        m.requests.load(Ordering::Relaxed),
        "hit + fallback must partition the dispatches"
    );
    // The hit is attributed to a specific replica in /metrics.
    let snap = stack.metrics_snapshot();
    assert!(
        snap.iter()
            .any(|(n, v)| n.starts_with("ps_replica_affinity_hits{") && *v > 0.0),
        "per-replica affinity series missing"
    );
}

#[test]
fn affinity_disabled_reproduces_legacy_routing() {
    let mut cfg = acfg();
    cfg.pool.affinity.enabled = false;
    let stack = LiveStack::start_sim(&cfg).unwrap();
    for i in 0..12 {
        let r = stack.complete(&format!("{PREAMBLE} question {i}"), 4).unwrap();
        assert!(!r.tokens.is_empty());
    }
    // Off = the exact pre-affinity fan-out: no placement decisions, no
    // transfers, no per-replica series — only the zeroed globals.
    let m = &stack.metrics;
    assert_eq!(m.affinity_hits.load(Ordering::Relaxed), 0);
    assert_eq!(m.affinity_fallbacks.load(Ordering::Relaxed), 0);
    assert_eq!(m.affinity_match_blocks.load(Ordering::Relaxed), 0);
    assert_eq!(m.kv_transfers.load(Ordering::Relaxed), 0);
    assert_eq!(m.kv_transfer_blocks.load(Ordering::Relaxed), 0);
    let snap = stack.metrics_snapshot();
    assert_eq!(
        snap.iter()
            .find(|(n, _)| n == "ps_affinity_hit_total")
            .map(|(_, v)| *v),
        Some(0.0)
    );
    assert!(
        snap.iter().all(|(n, _)| !n.starts_with("ps_replica_affinity")),
        "per-replica affinity series must not exist with affinity off"
    );
}

#[test]
fn saturated_hot_replica_spills_with_brokered_transfer_loss_free() {
    let mut cfg = acfg();
    // One slot, serial decode: the hot replica's private queue fills
    // well before a 48-request burst drains, forcing the router's
    // spill path (least-loaded peer + brokered block transfer).
    cfg.pool.max_inflight = 1;
    cfg.pool.max_decode_batch = 1;
    cfg.pool.queue_capacity = 256;
    let stack = Arc::new(LiveStack::start_sim(&cfg).unwrap());
    let m = &stack.metrics;
    // Warm until the router demonstrably matches an advertised prefix.
    for i in 0..40 {
        stack.complete(&format!("{PREAMBLE} warm {i}"), 2).unwrap();
        if m.affinity_hits.load(Ordering::Relaxed) > 0 {
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    assert!(m.affinity_hits.load(Ordering::Relaxed) > 0, "warm-up never hit");

    let n = 48u64;
    let handles: Vec<_> = (0..n)
        .map(|i| {
            let s = Arc::clone(&stack);
            std::thread::spawn(move || s.complete(&format!("{PREAMBLE} burst {i}"), 24))
        })
        .collect();
    for h in handles {
        let r = h
            .join()
            .unwrap()
            .expect("no request may be lost to the spill path");
        assert!(!r.tokens.is_empty(), "spilled request lost its tokens");
    }
    assert_eq!(m.errors.load(Ordering::Relaxed), 0, "spill must not error jobs");
    // The overflow actually took the transfer path: the donor exported
    // its cached prefix run to the cold peer at least once.
    assert!(
        m.kv_transfers.load(Ordering::Relaxed) > 0,
        "saturating the hot replica must broker a block transfer \
         (hits={}, fallbacks={})",
        m.affinity_hits.load(Ordering::Relaxed),
        m.affinity_fallbacks.load(Ordering::Relaxed),
    );
    assert!(
        m.kv_transfer_blocks.load(Ordering::Relaxed)
            >= m.kv_transfers.load(Ordering::Relaxed),
        "each transfer moves at least one block"
    );
}

#[test]
fn affinity_over_the_rpc_data_plane() {
    // The same convergence through real worker processes: hot summaries
    // ride heartbeat frames, the supervisor publishes them into the
    // replica cells, and direct-placed jobs drain ahead of tier work.
    let mut cfg = acfg();
    cfg.pool.substrate = SubstrateKind::Process;
    cfg.pool.worker_bin = Some(env!("CARGO_BIN_EXE_pick-and-spin").to_string());
    cfg.pool.worker_log_dir = std::env::var("PS_WORKER_LOG_DIR").ok();
    let stack = LiveStack::start_sim(&cfg).unwrap();
    let m = &stack.metrics;
    let mut hits = 0u64;
    for i in 0..60 {
        let r = stack.complete(&format!("{PREAMBLE} question {i}"), 4).unwrap();
        assert!(!r.tokens.is_empty());
        hits = m.affinity_hits.load(Ordering::Relaxed);
        if hits > 0 {
            break;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    assert!(hits > 0, "no affinity hit over the RPC plane (heartbeat ads)");
    assert_eq!(m.errors.load(Ordering::Relaxed), 0);
}

#[test]
fn completion_request_builder_and_deadline_override() {
    let stack = LiveStack::start_sim(&acfg()).unwrap();
    let r = stack
        .complete_request(
            CompletionRequest::new("what is 2 plus 2?")
                .max_tokens(6)
                .affinity_key("tenant-7"),
        )
        .unwrap();
    assert!(!r.tokens.is_empty());
    assert!(r.tokens.len() <= 6);
    // A per-request deadline overrides the global timeout: 2 ms cannot
    // cover a 256-token decode on the calibrated sim engine.
    let err = stack
        .complete_request(
            CompletionRequest::new("please summarize everything about alpha beta")
                .max_tokens(256)
                .deadline_s(0.002),
        )
        .expect_err("a 2ms deadline cannot cover a 256-token decode");
    assert!(format!("{err:#}").contains("timed out"), "{err:#}");
    assert!(stack.metrics.timeouts.load(Ordering::Relaxed) >= 1);
}

#[test]
fn http_completions_accept_affinity_and_deadline_fields() {
    use pick_and_spin::gateway::http::http_request;
    use pick_and_spin::gateway::serve_http;

    let stack = Arc::new(LiveStack::start_sim(&acfg()).unwrap());
    let srv = serve_http(Arc::clone(&stack), 0, 8).unwrap();
    let (status, body) = http_request(
        srv.port,
        "POST",
        "/v1/completions",
        Some(
            r#"{"prompt": "what is 1 plus 2?", "max_tokens": 5,
                "affinity_key": "sess-1", "deadline_s": 30.0}"#,
        ),
    )
    .unwrap();
    assert_eq!(status, 200, "body: {body}");
    let j = pick_and_spin::util::json::Json::parse(&body).unwrap();
    assert!(j.rarr("tokens").unwrap().len() <= 5);
    // "session" is accepted as an alias for affinity_key.
    let (status, body) = http_request(
        srv.port,
        "POST",
        "/v1/completions",
        Some(r#"{"prompt": "what is 1 plus 2?", "max_tokens": 5, "session": "sess-1"}"#),
    )
    .unwrap();
    assert_eq!(status, 200, "body: {body}");
    srv.stop();
}
