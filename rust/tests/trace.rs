//! End-to-end request tracing, across every layer it touches.
//!
//! The invariants under test:
//! * **Trace-off is PR-parity**: with `pool.trace.enabled = false` (the
//!   default) no trace contexts are minted, `/metrics` exports no
//!   `ps_span_seconds` series, the flight recorder stays empty, and
//!   token streams are bit-identical to a tracing-on run.
//! * **Complete, monotonic timelines** on both the thread and process
//!   substrates: every completed request's record carries `admit`,
//!   `queued`, `prefill`, and `decode` spans with end ≥ start and all
//!   spans anchored inside the request's lifetime — on the process
//!   substrate the prefill/decode spans crossed the RPC wire.
//! * **SIGKILL mid-decode keeps the trace**: a worker killed with
//!   in-flight work yields a trace containing a `requeue` span plus a
//!   `decode` span from the second attempt, and zero lost completions.
//! * **W3C interop**: an inbound `traceparent` header round-trips —
//!   the response echoes the same trace id in `x-trace-id` and the
//!   record lands in `/debug/traces` under that id.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use pick_and_spin::config::{Config, SubstrateKind};
use pick_and_spin::gateway::LiveStack;
use pick_and_spin::telemetry::trace::{SpanKind, TraceCtx, TraceRecord};
use pick_and_spin::testkit::wait_until;
use pick_and_spin::util::json::Json;

const WORKER_BIN: &str = env!("CARGO_BIN_EXE_pick-and-spin");

fn easy_prompt(i: usize) -> String {
    format!("what is {i} plus {i}?")
}

fn base_cfg() -> Config {
    let mut cfg = Config::default();
    cfg.pool.replicas = [1, 1, 1];
    cfg.pool.max_inflight = 8;
    cfg.pool.flush_timeout_s = 0.003;
    cfg.pool.scale_interval_s = 0.02;
    cfg.orchestrator.idle_timeout_s = 3600.0;
    cfg
}

fn traced_cfg() -> Config {
    let mut cfg = base_cfg();
    cfg.pool.trace.enabled = true;
    cfg.pool.trace.sample_rate = 1.0;
    cfg
}

fn process_cfg(mut cfg: Config) -> Config {
    cfg.pool.substrate = SubstrateKind::Process;
    cfg.pool.worker_bin = Some(WORKER_BIN.to_string());
    cfg.pool.worker_log_dir = std::env::var("PS_WORKER_LOG_DIR").ok();
    cfg
}

/// Serve `n` easy prompts concurrently with explicit trace ids
/// `base+i`; return index → token stream.
fn serve_traced(
    stack: &Arc<LiveStack>,
    n: usize,
    base: u128,
    max_new: usize,
) -> std::collections::BTreeMap<usize, Vec<i32>> {
    use pick_and_spin::gateway::CompletionRequest;
    let handles: Vec<_> = (0..n)
        .map(|i| {
            let s = Arc::clone(stack);
            std::thread::spawn(move || {
                let req = CompletionRequest::new(easy_prompt(i))
                    .max_tokens(max_new)
                    .trace_ctx(TraceCtx {
                        trace_id: base + i as u128,
                        sampled: true,
                    });
                (i, s.complete_request(req).expect("request").tokens)
            })
        })
        .collect();
    handles
        .into_iter()
        .map(|h| h.join().expect("request thread"))
        .collect()
}

fn find_record(stack: &LiveStack, trace_id: u128) -> Option<TraceRecord> {
    stack
        .metrics
        .recorder
        .snapshot()
        .into_iter()
        .find(|r| r.trace_id == trace_id)
}

/// Every span well-formed and anchored inside the request lifetime, and
/// the phase spans (admit/queued/prefill/decode) in causal order.
fn assert_timeline(r: &TraceRecord) {
    assert!(!r.spans.is_empty(), "empty timeline for {:032x}", r.trace_id);
    let end = r.start_s + r.total_s;
    for s in &r.spans {
        assert!(
            s.end_s >= s.start_s,
            "span {} runs backwards: [{}, {}]",
            s.kind.name(),
            s.start_s,
            s.end_s
        );
        assert!(
            s.start_s >= r.start_s - 1e-9 && s.end_s <= end + 1e-6,
            "span {} [{}, {}] outside request [{}, {}]",
            s.kind.name(),
            s.start_s,
            s.end_s,
            r.start_s,
            end
        );
    }
    let last_end = |kind: SpanKind| -> f64 {
        r.spans
            .iter()
            .filter(|s| s.kind == kind)
            .map(|s| s.end_s)
            .fold(f64::NAN, f64::max)
    };
    for kind in [SpanKind::Admit, SpanKind::Queued, SpanKind::Prefill, SpanKind::Decode] {
        assert!(
            r.spans.iter().any(|s| s.kind == kind),
            "timeline for {:032x} is missing `{}`: {:?}",
            r.trace_id,
            kind.name(),
            r.spans.iter().map(|s| s.kind.name()).collect::<Vec<_>>()
        );
    }
    assert!(last_end(SpanKind::Admit) <= last_end(SpanKind::Prefill) + 1e-9);
    assert!(last_end(SpanKind::Prefill) <= last_end(SpanKind::Decode) + 1e-9);
}

#[test]
fn trace_off_is_default_exports_nothing_and_tokens_match_trace_on() {
    let n = 16;
    let plain_stack = Arc::new(LiveStack::start_sim(&base_cfg()).unwrap());
    let plain = serve_traced(&plain_stack, n, 0x9000, 16);
    // Off (the default): no span series, no recorded traces, and the
    // explicit per-request ctx is ignored (no recorder to land in).
    let snap = plain_stack.metrics_snapshot();
    assert!(!snap.iter().any(|(k, _)| k.starts_with("ps_span_seconds")));
    assert!(plain_stack.metrics.recorder.snapshot().is_empty());
    assert!(!plain_stack.metrics.recorder.enabled());
    // The latency-breakdown histograms are always-on (satellite metrics,
    // not gated on tracing).
    assert!(snap.iter().any(|(k, _)| k.starts_with("ps_ttft_seconds")));
    assert!(snap.iter().any(|(k, _)| k.starts_with("ps_tpot_seconds")));
    drop(plain_stack);

    let stack = Arc::new(LiveStack::start_sim(&traced_cfg()).unwrap());
    let traced = serve_traced(&stack, n, 0x9000, 16);
    assert_eq!(plain, traced, "tracing changed the token stream");
    assert_eq!(stack.metrics.errors.load(Ordering::Relaxed), 0);
    // On: the same traffic now exports span histograms and records.
    assert!(
        wait_until(Duration::from_secs(5), || {
            stack.metrics.recorder.snapshot().len() >= n
        }),
        "recorder holds {} of {n} traces",
        stack.metrics.recorder.snapshot().len()
    );
    let snap = stack.metrics_snapshot();
    assert!(snap.iter().any(|(k, _)| k.starts_with("ps_span_seconds")));
}

#[test]
fn thread_substrate_traces_are_complete_and_monotonic() {
    let n = 8;
    let stack = Arc::new(LiveStack::start_sim(&traced_cfg()).unwrap());
    serve_traced(&stack, n, 0xA000, 12);
    assert!(
        wait_until(Duration::from_secs(5), || {
            (0..n).all(|i| find_record(&stack, 0xA000 + i as u128).is_some())
        }),
        "not every trace landed in the recorder"
    );
    for i in 0..n {
        let r = find_record(&stack, 0xA000 + i as u128).unwrap();
        assert_eq!(r.outcome, "ok");
        assert!(r.tokens > 0);
        assert_timeline(&r);
    }
}

#[test]
fn process_substrate_traces_cross_the_wire() {
    // Same timeline completeness, but prefill/decode spans originate
    // inside worker *processes* and come back over the RPC frames.
    let n = 8;
    let stack =
        Arc::new(LiveStack::start_sim(&process_cfg(traced_cfg())).unwrap());
    serve_traced(&stack, n, 0xB000, 12);
    assert!(
        wait_until(Duration::from_secs(10), || {
            (0..n).all(|i| find_record(&stack, 0xB000 + i as u128).is_some())
        }),
        "not every trace crossed the wire into the recorder"
    );
    for i in 0..n {
        let r = find_record(&stack, 0xB000 + i as u128).unwrap();
        assert_eq!(r.outcome, "ok");
        assert_timeline(&r);
    }
    assert_eq!(stack.metrics.errors.load(Ordering::Relaxed), 0);
}

#[test]
fn sigkill_mid_decode_trace_shows_requeue_and_second_decode() {
    // SIGKILL one of two small-tier workers with traffic in flight: the
    // supervisor requeues off its dispatch ledger, and the victims'
    // traces must show the `requeue` span plus a fresh `decode` span
    // from the second attempt — with zero lost completions.
    let mut cfg = process_cfg(traced_cfg());
    cfg.pool.replicas = [2, 1, 1];
    let stack = Arc::new(LiveStack::start_sim(&cfg).unwrap());
    let n = 48usize;
    let handles: Vec<_> = (0..n)
        .map(|i| {
            let s = Arc::clone(&stack);
            std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(i as u64 * 2));
                let req = pick_and_spin::gateway::CompletionRequest::new(
                    easy_prompt(i),
                )
                .max_tokens(24)
                .trace_ctx(TraceCtx { trace_id: 0xC000 + i as u128, sampled: true });
                s.complete_request(req)
            })
        })
        .collect();
    std::thread::sleep(Duration::from_millis(30));
    assert!(
        stack.inject_replica_failure(0),
        "no Ready small-tier worker to kill"
    );
    for h in handles {
        let r = h
            .join()
            .unwrap()
            .expect("completion lost across the SIGKILL");
        assert!(!r.tokens.is_empty());
    }
    assert_eq!(stack.metrics.completed.load(Ordering::Relaxed), n as u64);
    assert_eq!(stack.metrics.errors.load(Ordering::Relaxed), 0);
    assert!(
        stack.metrics.requeued.load(Ordering::Relaxed) >= 1,
        "in-flight jobs must requeue off the killed worker's ledger"
    );
    // At least one trace carries the scar: requeue + a decode that
    // finished on the survivor.
    assert!(
        wait_until(Duration::from_secs(10), || {
            stack.metrics.recorder.snapshot().iter().any(|r| {
                r.outcome == "ok"
                    && r.spans.iter().any(|s| s.kind == SpanKind::Requeue)
                    && r.spans.iter().any(|s| s.kind == SpanKind::Decode)
            })
        }),
        "no completed trace shows requeue + second decode"
    );
    let scarred: Vec<_> = stack
        .metrics
        .recorder
        .snapshot()
        .into_iter()
        .filter(|r| r.spans.iter().any(|s| s.kind == SpanKind::Requeue))
        .collect();
    for r in &scarred {
        assert_eq!(r.outcome, "ok", "requeued request must still complete");
        let requeue_end = r
            .spans
            .iter()
            .filter(|s| s.kind == SpanKind::Requeue)
            .map(|s| s.end_s)
            .fold(f64::NAN, f64::max);
        let decode_end = r
            .spans
            .iter()
            .filter(|s| s.kind == SpanKind::Decode)
            .map(|s| s.end_s)
            .fold(f64::NAN, f64::max);
        assert!(
            decode_end >= requeue_end,
            "second decode must finish after the requeue"
        );
    }
}

#[test]
fn traceparent_round_trips_over_http_and_lands_in_debug_traces() {
    use pick_and_spin::gateway::http::http_request_with_headers;
    use pick_and_spin::gateway::serve_http;

    let stack = Arc::new(LiveStack::start_sim(&traced_cfg()).unwrap());
    let srv = serve_http(Arc::clone(&stack), 0, 4).unwrap();
    let port = srv.port;
    let trace_hex = "4bf92f3577b34da6a3ce929d0e0e4736";
    let parent = format!("00-{trace_hex}-00f067aa0ba902b7-01");
    let (status, headers, body) = http_request_with_headers(
        port,
        "POST",
        "/v1/completions",
        &[("traceparent", &parent)],
        Some(r#"{"prompt": "what is 2 plus 2?", "max_tokens": 8}"#),
    )
    .unwrap();
    assert_eq!(status, 200, "body: {body}");
    let echoed = headers
        .iter()
        .find(|(k, _)| k.eq_ignore_ascii_case("x-trace-id"))
        .map(|(_, v)| v.as_str())
        .expect("response must echo x-trace-id");
    assert_eq!(echoed, trace_hex, "trace id must survive the round trip");

    // A request without a traceparent gets a freshly minted id.
    let (status, headers, _) = http_request_with_headers(
        port,
        "POST",
        "/v1/completions",
        &[],
        Some(r#"{"prompt": "what is 3 plus 3?", "max_tokens": 8}"#),
    )
    .unwrap();
    assert_eq!(status, 200);
    let minted = headers
        .iter()
        .find(|(k, _)| k.eq_ignore_ascii_case("x-trace-id"))
        .map(|(_, v)| v.clone())
        .expect("minted trace id missing");
    assert_eq!(minted.len(), 32);
    assert_ne!(minted, trace_hex);

    // Both traces are scrapeable at /debug/traces, newest first.
    assert!(wait_until(Duration::from_secs(5), || {
        let (s, b) =
            pick_and_spin::gateway::http::http_request(port, "GET", "/debug/traces", None)
                .unwrap();
        s == 200 && b.contains(trace_hex) && b.contains(&minted)
    }));
    let (s, b) = pick_and_spin::gateway::http::http_request(
        port,
        "GET",
        "/debug/traces?outcome=ok",
        None,
    )
    .unwrap();
    assert_eq!(s, 200);
    let arr = Json::parse(&b).unwrap();
    let arr = arr.as_arr().expect("traces body must be a JSON array");
    assert!(arr.len() >= 2);
    for rec in arr {
        assert_eq!(rec.rstr("outcome").unwrap(), "ok");
        assert!(!rec.rarr("spans").unwrap().is_empty());
    }
    // A filter that matches nothing returns an empty array, not an error.
    let (s, b) = pick_and_spin::gateway::http::http_request(
        port,
        "GET",
        "/debug/traces?outcome=shed&slow_ms=0",
        None,
    )
    .unwrap();
    assert_eq!(s, 200);
    assert_eq!(Json::parse(&b).unwrap().as_arr().unwrap().len(), 0);
    srv.stop();
}

#[test]
fn readyz_reports_per_tier_readiness() {
    use pick_and_spin::gateway::http::http_request;
    use pick_and_spin::gateway::serve_http;

    let stack = Arc::new(LiveStack::start_sim(&base_cfg()).unwrap());
    let srv = serve_http(Arc::clone(&stack), 0, 2).unwrap();
    let (s, b) = http_request(srv.port, "GET", "/healthz", None).unwrap();
    assert_eq!((s, b.as_str()), (200, "ok"));
    assert!(
        wait_until(Duration::from_secs(10), || {
            http_request(srv.port, "GET", "/readyz", None).unwrap().0 == 200
        }),
        "a fully provisioned pool never became ready"
    );
    let (_, b) = http_request(srv.port, "GET", "/readyz", None).unwrap();
    let j = Json::parse(&b).unwrap();
    assert!(j.bool_or("ready", false));
    let tiers = j.rarr("tiers").unwrap();
    assert_eq!(tiers.len(), 3);
    for t in tiers {
        assert!(t.bool_or("ready", false), "tier not ready: {}", t.dump());
        assert!(t.rf64("ready_replicas").unwrap() >= 1.0);
    }
    srv.stop();
}

#[test]
fn access_log_writes_one_json_line_per_request() {
    let log_path = std::env::temp_dir().join(format!(
        "ps-access-{}-{}.log",
        std::process::id(),
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_nanos())
            .unwrap_or(0)
    ));
    let log_str = log_path.to_str().unwrap().to_string();
    let mut cfg = traced_cfg();
    cfg.pool.trace.access_log = log_str.clone();
    let stack = Arc::new(LiveStack::start_sim(&cfg).unwrap());
    let n = 6;
    serve_traced(&stack, n, 0xD000, 8);
    assert!(
        wait_until(Duration::from_secs(5), || {
            std::fs::read_to_string(&log_str)
                .map(|s| s.lines().count() >= n)
                .unwrap_or(false)
        }),
        "access log never reached {n} lines"
    );
    let text = std::fs::read_to_string(&log_str).unwrap();
    for line in text.lines() {
        let j = Json::parse(line).expect("access log line must be JSON");
        assert_eq!(j.rstr("outcome").unwrap(), "ok");
        assert!(j.rf64("tokens").unwrap() > 0.0);
        assert_eq!(j.rstr("trace_id").unwrap().len(), 32);
        assert!(j.rf64("total_s").unwrap() >= 0.0);
    }
    assert_eq!(stack.metrics.access_log.dropped.load(Ordering::Relaxed), 0);
    drop(stack);
    let _ = std::fs::remove_file(&log_str);
}

#[test]
fn multi_host_traces_are_scrapeable_at_debug_traces() {
    // The full paper deployment shape: workers hosted by two real
    // `ps-node` agents on localhost TCP, tracing on — span timelines
    // must cross node agent → worker → supervisor and come out of the
    // `/debug/traces` scrape. When `PS_TRACE_DUMP` is set (CI), the
    // scraped dump is written there and uploaded as an artifact.
    use pick_and_spin::gateway::http::http_request;
    use pick_and_spin::gateway::serve_http;
    use std::process::{Command, Stdio};

    let free_port = || {
        std::net::TcpListener::bind("127.0.0.1:0")
            .unwrap()
            .local_addr()
            .unwrap()
            .port()
    };
    let spawn_agent = |name: &str| {
        let addr = format!("127.0.0.1:{}", free_port());
        let mut cmd = Command::new(WORKER_BIN);
        cmd.arg("ps-node")
            .arg("--listen")
            .arg(&addr)
            .arg("--slots")
            .arg("4")
            .arg("--name")
            .arg(name)
            .stdin(Stdio::null())
            .stdout(Stdio::null());
        if let Ok(dir) = std::env::var("PS_WORKER_LOG_DIR") {
            cmd.arg("--log-dir").arg(dir);
        }
        let child = cmd.spawn().expect("spawn ps-node agent");
        (addr, child)
    };
    let (addr0, mut agent0) = spawn_agent("trace-n0");
    let (addr1, mut agent1) = spawn_agent("trace-n1");

    let mut cfg = process_cfg(traced_cfg());
    cfg.pool.nodes.agents = vec![addr0, addr1];
    let stack = Arc::new(LiveStack::start_sim(&cfg).unwrap());
    let srv = serve_http(Arc::clone(&stack), 0, 4).unwrap();
    let n = 12;
    serve_traced(&stack, n, 0xE000, 12);

    let mut dump = String::new();
    assert!(
        wait_until(Duration::from_secs(10), || {
            let (s, b) =
                http_request(srv.port, "GET", "/debug/traces", None).unwrap();
            dump = b;
            s == 200
                && Json::parse(&dump)
                    .ok()
                    .and_then(|j| j.as_arr().map(|a| a.len()))
                    .unwrap_or(0)
                    >= n
        }),
        "multi-host traces never reached /debug/traces"
    );
    let j = Json::parse(&dump).unwrap();
    for rec in j.as_arr().unwrap() {
        assert_eq!(rec.rstr("trace_id").unwrap().len(), 32);
        assert!(!rec.rarr("spans").unwrap().is_empty());
    }
    if let Ok(path) = std::env::var("PS_TRACE_DUMP") {
        if let Some(dir) = std::path::Path::new(&path).parent() {
            let _ = std::fs::create_dir_all(dir);
        }
        std::fs::write(&path, &dump).expect("write trace dump");
    }
    srv.stop();
    drop(stack);
    let _ = agent0.kill();
    let _ = agent0.wait();
    let _ = agent1.kill();
    let _ = agent1.wait();
}

#[test]
fn sim_engine_emits_the_same_span_schema_on_virtual_time() {
    use pick_and_spin::baselines::SelectionPolicy;
    use pick_and_spin::sim::{Deployment, SimConfig};
    use pick_and_spin::workload::{OracleClassifier, TemplateLibrary};

    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/data/templates.json");
    if !std::path::Path::new(path).exists() {
        eprintln!("skipping: templates not built");
        return;
    }
    let lib = TemplateLibrary::load(path).unwrap();
    let mut sc = SimConfig::defaults();
    sc.deployment = Deployment::Static;
    sc.policy = SelectionPolicy::RoundRobin;
    sc.n_requests = 500;
    sc.rate_qps = 10.0;
    sc.pool.trace.enabled = true;
    let cls = Box::new(OracleClassifier::new(lib.clone(), 0.0, 1));
    let rep = pick_and_spin::sim::run(&sc, &lib, cls).unwrap();
    let with_spans = rep.records.iter().filter(|r| !r.spans.is_empty()).count();
    assert!(with_spans > 0, "sim emitted no span timelines");
    for r in &rep.records {
        let mut last_start = f64::NEG_INFINITY;
        for s in &r.spans {
            assert!(s.end_s >= s.start_s, "sim span runs backwards");
            assert!(s.start_s >= last_start, "sim spans out of order");
            last_start = s.start_s;
            // Same vocabulary as the live path: names round-trip.
            assert!(SpanKind::from_name(s.kind.name()).is_some());
        }
        if r.success {
            for kind in [SpanKind::Admit, SpanKind::Queued, SpanKind::Prefill, SpanKind::Decode]
            {
                assert!(
                    r.spans.iter().any(|s| s.kind == kind),
                    "sim success timeline missing `{}`",
                    kind.name()
                );
            }
        }
    }

    // Trace off: identical schema switch — records carry no spans.
    sc.pool.trace.enabled = false;
    let cls = Box::new(OracleClassifier::new(lib.clone(), 0.0, 1));
    let rep = pick_and_spin::sim::run(&sc, &lib, cls).unwrap();
    assert!(rep.records.iter().all(|r| r.spans.is_empty()));
}
