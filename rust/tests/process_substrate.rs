//! Process-substrate integration: replica workers as real OS processes
//! (`ps-replica` subcommand of the gateway binary) behind the RPC data
//! plane. These tests spawn actual worker processes — Cargo builds the
//! binary for integration tests and exposes it via `CARGO_BIN_EXE_*` —
//! and drive the full gateway path over Unix-socket framed JSON RPC:
//! conformance against the shared `Substrate` contract, batched decode,
//! cancellation propagation, scale-to-zero + cold wake, and the headline
//! capability the thread substrate fundamentally cannot model: a worker
//! SIGKILLed mid-decode (`kill -9`) recovering loss-free.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use pick_and_spin::config::{Config, SubstrateKind};
use pick_and_spin::gateway::LiveStack;
use pick_and_spin::models::zoo;
use pick_and_spin::registry::Registry;
use pick_and_spin::substrate::remote::{ProcessSubstrate, WorkerSpec};
use pick_and_spin::testkit::substrate_conformance::{check, Driver};

const WORKER_BIN: &str = env!("CARGO_BIN_EXE_pick-and-spin");

fn pcfg() -> Config {
    let mut cfg = Config::default();
    cfg.pool.substrate = SubstrateKind::Process;
    cfg.pool.worker_bin = Some(WORKER_BIN.to_string());
    // CI sets PS_WORKER_LOG_DIR and uploads the logs as artifacts.
    cfg.pool.worker_log_dir = std::env::var("PS_WORKER_LOG_DIR").ok();
    cfg.pool.replicas = [1, 1, 1];
    cfg.pool.max_inflight = 16;
    cfg.pool.flush_timeout_s = 0.003;
    cfg.pool.scale_interval_s = 0.05;
    cfg
}

fn metric(stack: &LiveStack, name: &str) -> f64 {
    stack
        .metrics_snapshot()
        .into_iter()
        .find(|(n, _)| n == name)
        .map(|(_, v)| v)
        .unwrap_or_else(|| panic!("{name} missing from /metrics"))
}

#[test]
fn process_substrate_passes_conformance() {
    // The same lifecycle contract MockSubstrate and LocalSubstrate run —
    // here every provision spawns a real worker process.
    let cfg = pcfg();
    let z = zoo();
    let registry = Registry::new(&z, 300.0);
    let mut pool = cfg.pool.clone();
    pool.replicas = [2, 2, 2];
    let spec = WorkerSpec::from_pool(&pool, &["--engine", "sim"]).unwrap();
    let mut sub = ProcessSubstrate::standalone(pool, &registry, spec);
    let epoch = sub.epoch();
    let sid = sub.tier_service(0);
    let (mspec, backend) = {
        let s = registry.get(sid);
        (s.spec.clone(), s.backend)
    };
    let mut d = Driver {
        substrate: &mut sub,
        service: sid,
        model_idx: 0,
        spec: mspec,
        backend,
        clock: Box::new(move || {
            std::thread::sleep(Duration::from_millis(5));
            epoch.elapsed().as_secs_f64()
        }),
        timeout_s: 30.0,
    };
    check(&mut d);
    drop(d);
    sub.shutdown();
}

#[test]
fn rpc_pool_serves_concurrent_load_with_batched_decode() {
    // The full engine-pool path end-to-end over the RPC data plane:
    // router thread → tier queues → pump threads → worker processes →
    // streamed token chunks back. Decode batching must engage inside the
    // workers and surface through heartbeat counters at /metrics.
    let stack = Arc::new(LiveStack::start_sim(&pcfg()).unwrap());
    let n = 32u64;
    let handles: Vec<_> = (0..n)
        .map(|i| {
            let s = Arc::clone(&stack);
            std::thread::spawn(move || {
                s.complete(&format!("what is {i} plus {i}?"), 16).unwrap()
            })
        })
        .collect();
    let mut total_tokens = 0usize;
    for h in handles {
        let r = h.join().unwrap();
        assert!(!r.tokens.is_empty());
        assert!(r.latency_s >= r.ttft_s, "latency below TTFT");
        assert!(r.queue_wait_s >= 0.0);
        total_tokens += r.tokens.len();
    }
    let m = &stack.metrics;
    assert_eq!(m.requests.load(Ordering::Relaxed), n);
    assert_eq!(m.completed.load(Ordering::Relaxed), n);
    assert_eq!(m.errors.load(Ordering::Relaxed), 0);
    assert_eq!(m.tokens_out.load(Ordering::Relaxed) as usize, total_tokens);
    // Worker-side counters arrive via heartbeats (≤ 20 ms cadence).
    let deadline = Instant::now() + Duration::from_secs(5);
    while m.batched.load(Ordering::Relaxed) == 0 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(10));
    }
    assert!(
        m.batched.load(Ordering::Relaxed) > 0,
        "no batched decode steps under 32-way concurrency over RPC"
    );
    // The RPC plane itself is observable: frames flowed both ways and
    // Ping→Pong latency was measured.
    assert!(metric(&stack, "ps_rpc_frames_sent_total") > 0.0);
    assert!(metric(&stack, "ps_rpc_frames_recv_total") > 0.0);
    if metric(&stack, "ps_rpc_pings_total") > 0.0 {
        assert!(metric(&stack, "ps_rpc_rtt_seconds_total") >= 0.0);
    }
}

#[test]
fn rpc_cancellation_propagates_and_frees_worker_slots() {
    // A timed-out caller fires its cancel token gateway-side; the pump
    // ships a Cancel frame; the worker evicts the sequence mid-decode
    // and the slot frees (observable through heartbeat inflight).
    let mut cfg = pcfg();
    cfg.gateway.request_timeout_s = 0.01;
    let stack = LiveStack::start_sim(&cfg).unwrap();
    let err = stack
        .complete("please summarize everything about alpha beta gamma", 256)
        .expect_err("a 10ms timeout cannot cover a ~50ms decode");
    assert!(format!("{err:#}").contains("timed out"), "{err:#}");
    assert_eq!(stack.metrics.timeouts.load(Ordering::Relaxed), 1);
    let deadline = Instant::now() + Duration::from_secs(10);
    while (stack.metrics.cancelled.load(Ordering::Relaxed) == 0
        || stack.slots_in_use() > 0)
        && Instant::now() < deadline
    {
        std::thread::sleep(Duration::from_millis(5));
    }
    assert!(
        stack.metrics.cancelled.load(Ordering::Relaxed) >= 1,
        "timeout must cancel the in-flight sequence across the RPC boundary"
    );
    assert_eq!(stack.slots_in_use(), 0, "cancelled slot must free");
}

#[test]
fn rpc_pool_scales_to_zero_and_cold_wakes_workers() {
    // Scale-to-zero terminates worker *processes* (graceful Terminate →
    // Gone → exit 0); a cold wake spawns a fresh process and pays the
    // real spawn→Ready cold start, which feeds Alg. 2.
    let mut cfg = pcfg();
    cfg.orchestrator.idle_timeout_s = 0.2;
    cfg.orchestrator.warm_pool = [1, 0, 0];
    let stack = LiveStack::start_sim(&cfg).unwrap();
    assert_eq!(stack.active_replicas(), 3);

    stack.complete("what is 2 plus 2?", 4).unwrap();
    let deadline = Instant::now() + Duration::from_secs(15);
    while stack.active_replicas() > 1 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(25));
    }
    assert_eq!(
        stack.active_replicas(),
        1,
        "idle tiers must park their worker processes to the warm floor"
    );

    let r = stack
        .complete("prove that the sum converges and derive a closed form", 6)
        .unwrap();
    assert!(!r.tokens.is_empty());
    assert!(
        stack.metrics.cold_wakes.load(Ordering::Relaxed) >= 1,
        "serving a parked tier must count a cold wake"
    );
}

#[test]
fn sigkilled_worker_recovers_loss_free_with_measured_recovery() {
    // The acceptance scenario: SIGKILL a worker process mid-decode (the
    // fault a thread substrate cannot model — the address space is
    // gone). Every in-flight job must requeue off the supervisor's
    // dispatch ledger and complete on the survivor/replacement, the
    // replica must re-spawn through Scheduled→Loading→Ready, and
    // /metrics must show the incident with a measured recovery time.
    let mut cfg = pcfg();
    cfg.pool.replicas = [2, 1, 1];
    cfg.pool.max_inflight = 8;
    cfg.orchestrator.idle_timeout_s = 3600.0;
    let stack = Arc::new(LiveStack::start_sim(&cfg).unwrap());
    assert_eq!(stack.active_replicas(), 4);

    let n = 48u64;
    let handles: Vec<_> = (0..n)
        .map(|i| {
            let s = Arc::clone(&stack);
            std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(i * 2));
                s.complete(&format!("what is {i} plus {i}?"), 24)
            })
        })
        .collect();

    // kill -9 one small-tier worker once traffic is flowing.
    std::thread::sleep(Duration::from_millis(30));
    assert!(
        stack.inject_replica_failure(0),
        "no Ready small-tier worker to kill"
    );

    for h in handles {
        let r = h
            .join()
            .unwrap()
            .expect("request lost across a SIGKILLed worker");
        assert!(!r.tokens.is_empty());
    }

    let deadline = Instant::now() + Duration::from_secs(15);
    loop {
        let incidents = stack.metrics.incidents.load(Ordering::Relaxed);
        let recovered = stack.metrics.recovered.load(Ordering::Relaxed);
        if incidents >= 1 && recovered >= 1 {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "incident never recovered: incidents={incidents} recovered={recovered}"
        );
        std::thread::sleep(Duration::from_millis(20));
    }
    assert_eq!(
        stack.active_replicas(),
        4,
        "the re-spawned worker must restore the fleet"
    );
    assert!(
        stack.metrics.requeued.load(Ordering::Relaxed) >= 1,
        "in-flight jobs must requeue off the killed worker's ledger"
    );
    assert!(metric(&stack, "ps_incidents_total") >= 1.0);
    assert!(metric(&stack, "ps_recovered_total") >= 1.0);
    assert!(
        metric(&stack, "ps_recovery_seconds_total") > 0.0,
        "recovery time must be measured and nonzero"
    );
    assert_eq!(stack.metrics.errors.load(Ordering::Relaxed), 0);
    assert_eq!(stack.metrics.completed.load(Ordering::Relaxed), n);
}

#[test]
fn rpc_graceful_drain_returns_unstarted_jobs() {
    // Scale-down over RPC: Terminate → the worker sends Returned frames
    // for work it never started, finishes its decoding slots, exits 0 —
    // and every caller still completes.
    let mut cfg = pcfg();
    cfg.pool.max_inflight = 4;
    cfg.pool.max_prefill_batch = 1;
    cfg.orchestrator.idle_timeout_s = 3600.0;
    let stack = Arc::new(LiveStack::start_sim(&cfg).unwrap());
    let n = 12u64;
    let handles: Vec<_> = (0..n)
        .map(|i| {
            let s = Arc::clone(&stack);
            std::thread::spawn(move || s.complete(&format!("what is {i} plus {i}?"), 48))
        })
        .collect();
    std::thread::sleep(Duration::from_millis(10));
    assert!(stack.drain_replica(0), "no Ready small-tier worker to drain");
    for h in handles {
        let r = h
            .join()
            .unwrap()
            .expect("request lost across an RPC graceful drain");
        assert!(!r.tokens.is_empty());
    }
    assert_eq!(stack.metrics.completed.load(Ordering::Relaxed), n);
    assert_eq!(stack.metrics.errors.load(Ordering::Relaxed), 0);
}
