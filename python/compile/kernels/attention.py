"""Multi-head attention (prefill) Pallas kernel — causal and encoder modes.

One grid step computes a full (batch, head) pair: scores, masking,
numerically-stable softmax, and the value contraction, all in VMEM —
a flash-attention-style fusion adapted to TPU.  The paper's GPU backends
(vLLM / TensorRT-LLM) express this schedule with threadblocks over
(batch, head); here the Pallas grid plays that role and BlockSpec's index
map expresses the HBM→VMEM tile schedule.

Lengths are per-example ([B] i32) so one compiled prefill serves ragged
batches — the BlockSpec index map routes row ``i // H`` of the length
column to grid step ``i``, the Pallas idiom for per-program scalars
(scalar-prefetch on real TPU; an SMEM-like broadcast block under
interpret mode).

For the tier sizes in this library (S ≤ 128, Dh = 24..32) an entire head's
Q/K/V and the [S, S] score tile fit comfortably in VMEM, so no kv-chunked
online softmax is needed; the VMEM assertion keeps that invariant honest
if shapes grow.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, NEG_INF, assert_vmem_ok


def _mha_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, *, causal: bool):
    q = q_ref[0]          # [S, Dh]
    k = k_ref[0]
    v = v_ref[0]
    length = len_ref[0, 0]
    s, dh = q.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, q.dtype))
    scores = jnp.dot(q, k.T) * scale                     # [S, S]
    qi = jax.lax.broadcasted_iota(jnp.int32, (s, s), 0)
    kj = jax.lax.broadcasted_iota(jnp.int32, (s, s), 1)
    mask = kj < length
    if causal:
        mask = mask & (kj <= qi)
    scores = jnp.where(mask, scores, NEG_INF)
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o_ref[0] = jnp.dot(p, v)


def _attention(q, k, v, lengths, *, causal: bool) -> jnp.ndarray:
    b, h, s, dh = q.shape
    assert_vmem_ok("attention_prefill",
                   [(s, dh)] * 4 + [(s, s)])  # q,k,v,o + score tile
    len_arr = jnp.reshape(lengths.astype(jnp.int32), (b, 1))
    qf = q.reshape(b * h, s, dh)
    kf = k.reshape(b * h, s, dh)
    vf = v.reshape(b * h, s, dh)
    out = pl.pallas_call(
        functools.partial(_mha_kernel, causal=causal),
        out_shape=jax.ShapeDtypeStruct((b * h, s, dh), q.dtype),
        grid=(b * h,),
        in_specs=[
            pl.BlockSpec((1, s, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, 1), lambda i: (i // h, 0)),
        ],
        out_specs=pl.BlockSpec((1, s, dh), lambda i: (i, 0, 0)),
        interpret=INTERPRET,
    )(qf, kf, vf, len_arr)
    return out.reshape(b, h, s, dh)


def attention_prefill(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                      lengths: jnp.ndarray) -> jnp.ndarray:
    """Causal MHA over padded prefill inputs (decoder LM).

    q, k, v: [B, H, S, Dh]; lengths: [B] i32 valid prompt lengths.
    Returns [B, H, S, Dh].
    """
    return _attention(q, k, v, lengths, causal=True)


def attention_encoder(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                      lengths: jnp.ndarray) -> jnp.ndarray:
    """Bidirectional MHA with padding mask (DistilBERT-lite encoder)."""
    return _attention(q, k, v, lengths, causal=False)
