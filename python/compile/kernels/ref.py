"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

These are also the *differentiable* implementations used by the classifier
training loop (`pallas_call` has no automatic VJP); pytest asserts that the
kernel-backed forward matches these references to tight tolerances, so
weights trained against the references serve identically through the
kernel path.
"""

from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


def layernorm(x: jnp.ndarray, gamma: jnp.ndarray, beta: jnp.ndarray,
              eps: float = 1e-5) -> jnp.ndarray:
    """LayerNorm over the last axis."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * gamma + beta


def gelu(x: jnp.ndarray) -> jnp.ndarray:
    """tanh-approximation GeLU (matches the Pallas kernel exactly)."""
    c = jnp.sqrt(2.0 / jnp.pi).astype(x.dtype)
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x**3)))


def ffn(x: jnp.ndarray, w1: jnp.ndarray, b1: jnp.ndarray,
        w2: jnp.ndarray, b2: jnp.ndarray) -> jnp.ndarray:
    """Fused feed-forward: GeLU(x@w1+b1)@w2+b2."""
    return gelu(x @ w1 + b1) @ w2 + b2


def _attention(q, k, v, lengths, causal):
    b, h, s, dh = q.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, q.dtype))
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    qi = jnp.arange(s)[:, None]
    kj = jnp.arange(s)[None, :]
    in_len = kj[None] < lengths.reshape(b, 1, 1)          # [B, S, S]
    mask = in_len & (kj <= qi)[None] if causal else in_len
    scores = jnp.where(mask[:, None], scores, NEG_INF)
    p = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v)


def attention_prefill(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                      lengths: jnp.ndarray) -> jnp.ndarray:
    """Causal multi-head attention over a padded prefill window.

    q, k, v: [B, H, S, Dh]; lengths: [B] i32 — positions >= lengths[b] are
    padding and are masked out of the keys (queries there produce garbage
    that downstream code never reads).
    """
    return _attention(q, k, v, lengths, causal=True)


def attention_encoder(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                      lengths: jnp.ndarray) -> jnp.ndarray:
    """Bidirectional multi-head attention with padding mask."""
    return _attention(q, k, v, lengths, causal=False)


def attention_decode(q: jnp.ndarray, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray, pos: jnp.ndarray) -> jnp.ndarray:
    """Single-position decode attention over a KV cache.

    q: [B, H, Dh] (the new position's query, already written to cache at
    index ``pos[b]``); k_cache, v_cache: [B, H, Smax, Dh]; pos: [B] i32.
    Each sequence attends to cache positions j <= pos[b].
    """
    b, h, smax, dh = k_cache.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, q.dtype))
    scores = jnp.einsum("bhd,bhkd->bhk", q, k_cache) * scale
    mask = jnp.arange(smax)[None, None, :] <= pos.reshape(b, 1, 1)
    scores = jnp.where(mask, scores, NEG_INF)
    p = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("bhk,bhkd->bhd", p, v_cache)


def classifier_head(h_cls: jnp.ndarray, w: jnp.ndarray,
                    b: jnp.ndarray) -> jnp.ndarray:
    """CLS projection + softmax: [B, D] @ [D, C] + [C] -> probs [B, C]."""
    logits = h_cls @ w + b
    z = logits - jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(z)
    return e / jnp.sum(e, axis=-1, keepdims=True)
