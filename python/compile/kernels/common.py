"""Shared Pallas kernel configuration.

All kernels run with ``interpret=True``: the CPU PJRT plugin (and the
``xla`` crate's CPU client on the Rust side) cannot execute Mosaic
custom-calls, so interpret mode lowers each kernel to plain HLO ops that
round-trip through the AOT HLO-text pipeline.  Real-TPU performance is
*estimated* from the BlockSpec-implied VMEM footprint and MXU utilization
(see DESIGN.md §Perf and ``vmem_report`` below) rather than measured.
"""

from __future__ import annotations

INTERPRET = True

NEG_INF = -1e30

# TPU v4-ish budget used for the static VMEM feasibility check.
VMEM_BYTES = 16 * 1024 * 1024
MXU_DIM = 128  # systolic array edge
LANE = 128     # last-dim tiling
SUBLANE = 8    # second-to-last-dim tiling (f32)


def vmem_footprint(block_shapes: list[tuple[int, ...]],
                   dtype_bytes: int = 4) -> int:
    """Bytes of VMEM used by one grid step holding the given blocks."""
    total = 0
    for shape in block_shapes:
        n = 1
        for d in shape:
            n *= d
        total += n * dtype_bytes
    return total


def assert_vmem_ok(name: str, block_shapes: list[tuple[int, ...]],
                   dtype_bytes: int = 4) -> int:
    """Static check that a kernel's working set fits the VMEM budget."""
    used = vmem_footprint(block_shapes, dtype_bytes)
    if used > VMEM_BYTES:
        raise ValueError(
            f"kernel {name}: VMEM working set {used} B exceeds budget "
            f"{VMEM_BYTES} B — shrink the block shapes"
        )
    return used


def mxu_utilization(m: int, n: int, k: int) -> float:
    """Fraction of MXU lanes busy for an (m,k)x(k,n) matmul tile.

    The systolic array processes MXU_DIM x MXU_DIM tiles; dimensions that
    are not multiples waste lanes on the ragged edge.  This is the number
    the §Perf report tracks per kernel.
    """
    def eff(d: int) -> float:
        if d >= MXU_DIM:
            full = d // MXU_DIM
            rem = d % MXU_DIM
            return (full * MXU_DIM + rem) / ((full + (1 if rem else 0)) * MXU_DIM)
        return d / MXU_DIM

    return eff(m) * eff(n) * eff(k)
