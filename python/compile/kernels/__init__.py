"""Pallas kernels (L1) and their pure-jnp oracles (ref.py)."""

from .attention import attention_encoder, attention_prefill
from .classifier_head import classifier_head
from .decode import attention_decode
from .ffn import ffn
from .layernorm import layernorm

__all__ = [
    "attention_prefill",
    "attention_encoder",
    "attention_decode",
    "classifier_head",
    "ffn",
    "layernorm",
]
