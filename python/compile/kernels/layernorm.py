"""Fused LayerNorm Pallas kernel.

One grid step normalizes a block of rows entirely in VMEM: mean/variance
reduction, scale and shift in a single pass — the fusion the paper's
backends get from vLLM/TensorRT layer-norm plugins.

TPU mapping: rows tile the sublane axis (multiples of 8), the model dim
lives on the lane axis (multiples of 128 for the medium/large tiers; the
small tier's d=64 under-fills lanes and is padded by Mosaic — documented
in the §Perf kernel table).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, assert_vmem_ok


def _ln_kernel(x_ref, gamma_ref, beta_ref, o_ref, *, eps: float):
    x = x_ref[...]
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    o_ref[...] = (x - mu) / jnp.sqrt(var + eps) * gamma_ref[...] + beta_ref[...]


def layernorm(x: jnp.ndarray, gamma: jnp.ndarray, beta: jnp.ndarray,
              eps: float = 1e-5, block_rows: int = 64) -> jnp.ndarray:
    """LayerNorm over the last axis of a [N, D] array."""
    n, d = x.shape
    bn = min(block_rows, n)
    # Grid only divides evenly in this library (shapes are static).
    while n % bn:
        bn -= 1
    assert_vmem_ok("layernorm", [(bn, d), (bn, d), (d,), (d,)])
    grid = (n // bn,)
    return pl.pallas_call(
        functools.partial(_ln_kernel, eps=eps),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bn, d), lambda i: (i, 0)),
        interpret=INTERPRET,
    )(x, gamma, beta)
