"""Single-token decode attention over a KV cache — the serving hot path.

Each grid step handles one (batch, head) pair: the new query attends to
all cache positions j <= pos with a fused masked softmax.  This is the
PagedAttention-style decode kernel of the paper's vLLM backend rethought
for TPU: instead of warps gathering KV blocks from GPU global memory, the
BlockSpec index map streams the head's [Smax, Dh] cache slab HBM→VMEM and
the mask (rather than a page table) bounds the valid window.  The Rust
coordinator's block-granular KV manager (rust/src/backend/kv_cache.rs)
supplies the ``pos`` watermark per sequence.

VMEM working set per step: 2·Smax·Dh + Smax + 2·Dh floats — tiny for the
tier sizes here; the assertion keeps it honest.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, NEG_INF, assert_vmem_ok


def _decode_kernel(q_ref, k_ref, v_ref, pos_ref, o_ref):
    q = q_ref[0]              # [Dh]
    k = k_ref[0]              # [Smax, Dh]
    v = v_ref[0]
    pos = pos_ref[0, 0]
    smax, dh = k.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, q.dtype))
    scores = jnp.dot(k, q) * scale                      # [Smax]
    j = jax.lax.broadcasted_iota(jnp.int32, (smax,), 0)
    scores = jnp.where(j <= pos, scores, NEG_INF)
    m = jnp.max(scores)
    p = jnp.exp(scores - m)
    p = p / jnp.sum(p)
    o_ref[0] = jnp.dot(p, v)                            # [Dh]


def attention_decode(q: jnp.ndarray, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray, pos: jnp.ndarray) -> jnp.ndarray:
    """Decode attention: q [B, H, Dh], caches [B, H, Smax, Dh], pos [B] i32.

    Positions are per-sequence — a continuous-batching decode step serves
    sequences at different depths in one kernel launch, exactly what the
    Rust batcher produces.  The caller must already have written this
    step's K/V at each sequence's ``pos``.  Returns [B, H, Dh].
    """
    b, h, smax, dh = k_cache.shape
    assert_vmem_ok("attention_decode", [(smax, dh), (smax, dh), (dh,), (dh,)])
    pos_arr = jnp.reshape(pos.astype(jnp.int32), (b, 1))
    qf = q.reshape(b * h, dh)
    kf = k_cache.reshape(b * h, smax, dh)
    vf = v_cache.reshape(b * h, smax, dh)
    out = pl.pallas_call(
        _decode_kernel,
        out_shape=jax.ShapeDtypeStruct((b * h, dh), q.dtype),
        grid=(b * h,),
        in_specs=[
            pl.BlockSpec((1, dh), lambda i: (i, 0)),
            pl.BlockSpec((1, smax, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, smax, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, 1), lambda i: (i // h, 0)),
        ],
        out_specs=pl.BlockSpec((1, dh), lambda i: (i, 0)),
        interpret=INTERPRET,
    )(qf, kf, vf, pos_arr)
    return out.reshape(b, h, dh)
