"""Fused feed-forward (W1 → GeLU → W2) Pallas kernel.

The paper's serving backends fuse the MLP block to avoid materializing the
[rows, ffn_dim] intermediate in HBM.  Here one grid step streams a block of
rows through both matmuls while the intermediate stays in VMEM — the
Pallas/TPU analogue of the CUDA fused-MLP epilogue.

TPU mapping: both matmuls hit the MXU; ffn dims are multiples of 128
(medium/large tiers) so lane utilization is full.  The weights for the
tier sizes used here (≤ 256×1024) fit VMEM whole, so they are loaded once
per grid step rather than tiled over k.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, assert_vmem_ok


def _gelu(x):
    c = jnp.sqrt(2.0 / jnp.pi).astype(x.dtype)
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x**3)))


def _ffn_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    x = x_ref[...]
    h = _gelu(jnp.dot(x, w1_ref[...]) + b1_ref[...])
    o_ref[...] = jnp.dot(h, w2_ref[...]) + b2_ref[...]


def ffn(x: jnp.ndarray, w1: jnp.ndarray, b1: jnp.ndarray,
        w2: jnp.ndarray, b2: jnp.ndarray, block_rows: int = 64) -> jnp.ndarray:
    """Fused GeLU MLP over a [N, D] input; w1: [D, F], w2: [F, D]."""
    n, d = x.shape
    f = w1.shape[1]
    bn = min(block_rows, n)
    while n % bn:
        bn -= 1
    assert_vmem_ok("ffn", [(bn, d), (d, f), (f,), (f, d), (d,), (bn, f), (bn, d)])
    grid = (n // bn,)
    return pl.pallas_call(
        _ffn_kernel,
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((d, f), lambda i: (0, 0)),
            pl.BlockSpec((f,), lambda i: (0,)),
            pl.BlockSpec((f, d), lambda i: (0, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bn, d), lambda i: (i, 0)),
        interpret=INTERPRET,
    )(x, w1, b1, w2, b2)
