"""Classifier head Pallas kernel: CLS projection + softmax (paper Eq. 3/4).

p_k = softmax(W · h_[CLS] + b) — the routing decision's final compute.
Fused into one VMEM-resident step so the router's semantic path adds a
single kernel after the encoder.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, assert_vmem_ok


def _head_kernel(h_ref, w_ref, b_ref, o_ref):
    logits = jnp.dot(h_ref[...], w_ref[...]) + b_ref[...]   # [B, C]
    z = logits - jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(z)
    o_ref[...] = e / jnp.sum(e, axis=-1, keepdims=True)


def classifier_head(h_cls: jnp.ndarray, w: jnp.ndarray,
                    b: jnp.ndarray) -> jnp.ndarray:
    """h_cls: [B, D], w: [D, C], b: [C] → class probabilities [B, C]."""
    bsz, d = h_cls.shape
    c = w.shape[1]
    assert_vmem_ok("classifier_head", [(bsz, d), (d, c), (c,), (bsz, c)])
    return pl.pallas_call(
        _head_kernel,
        out_shape=jax.ShapeDtypeStruct((bsz, c), h_cls.dtype),
        in_specs=[
            pl.BlockSpec((bsz, d), lambda: (0, 0)),
            pl.BlockSpec((d, c), lambda: (0, 0)),
            pl.BlockSpec((c,), lambda: (0,)),
        ],
        out_specs=pl.BlockSpec((bsz, c), lambda: (0, 0)),
        interpret=INTERPRET,
    )(h_cls, w, b)
