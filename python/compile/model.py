"""L2 — JAX compute graphs: decoder-LM tiers and the DistilBERT-lite router.

Every forward exists in two numerically-identical variants selected by
``use_kernels``:

* ``use_kernels=True``  — Pallas kernels (L1); this is what ``aot.py``
  lowers to HLO for the Rust serving path.
* ``use_kernels=False`` — the pure-jnp oracle (``kernels/ref.py``); this
  is differentiable and is what ``train_classifier.py`` optimizes.

pytest asserts the two agree to tight tolerances, so weights trained on
the reference serve identically through the kernel path.

Parameters are flat *lists* of arrays in the canonical order given by
``param_names`` — the same order ``aot.py`` writes to the ``.psw`` weight
file and the Rust runtime feeds to PJRT, so there is no pytree-ordering
ambiguity across the language boundary.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from . import tokenizer as tok
from .kernels import (
    attention_decode,
    attention_encoder,
    attention_prefill,
    classifier_head,
    ffn,
    layernorm,
)
from .kernels import ref


@dataclass(frozen=True)
class ModelConfig:
    """Static architecture of one compiled model."""

    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    d_head: int
    d_ffn: int
    seq_prefill: int
    seq_max: int
    n_classes: int = 0  # 0 => decoder LM, >0 => encoder classifier

    @property
    def is_classifier(self) -> bool:
        return self.n_classes > 0

    def param_count(self) -> int:
        n = self.vocab * self.d_model + self.seq_max * self.d_model
        per_layer = (
            4 * self.d_model * (self.n_heads * self.d_head)
            + 2 * self.d_model * self.d_ffn
            + self.d_ffn
            + self.d_model
            + 4 * self.d_model
        )
        n += self.n_layers * per_layer + 2 * self.d_model
        if self.is_classifier:
            n += self.d_model * self.n_classes + self.n_classes
        else:
            n += self.d_model * self.vocab
        return n


# The three serving tiers (paper: Gemma-3 27B / Llama-3 90B / Qwen-3 235B +
# DeepSeek-R1 685B collapse onto small/medium/large; see DESIGN.md
# §Substitutions).  Dims are MXU/lane-friendly multiples.
TIERS: dict[str, ModelConfig] = {
    "small": ModelConfig("small", tok.VOCAB, 64, 2, 2, 32, 256, 64, 96),
    "medium": ModelConfig("medium", tok.VOCAB, 128, 4, 4, 32, 512, 64, 96),
    "large": ModelConfig("large", tok.VOCAB, 256, 6, 8, 32, 1024, 64, 96),
}

# DistilBERT-lite complexity classifier (paper: DistilBERT, 3-way).
CLASSIFIER = ModelConfig(
    "classifier", tok.VOCAB, 96, 2, 4, 24, 384, tok.SEQ_CLS, tok.SEQ_CLS,
    n_classes=3,
)


def param_names(cfg: ModelConfig) -> list[str]:
    """Canonical parameter order shared with aot.py / .psw / Rust."""
    names = ["embed", "pos_embed"]
    for i in range(cfg.n_layers):
        names += [
            f"l{i}.ln1.g", f"l{i}.ln1.b",
            f"l{i}.wq", f"l{i}.wk", f"l{i}.wv", f"l{i}.wo",
            f"l{i}.ln2.g", f"l{i}.ln2.b",
            f"l{i}.w1", f"l{i}.b1", f"l{i}.w2", f"l{i}.b2",
        ]
    names += ["ln_f.g", "ln_f.b"]
    if cfg.is_classifier:
        names += ["head.w", "head.b"]
    else:
        names += ["w_out"]
    return names


def param_shapes(cfg: ModelConfig) -> dict[str, tuple[int, ...]]:
    d, dh, h, f = cfg.d_model, cfg.d_head, cfg.n_heads, cfg.d_ffn
    shapes: dict[str, tuple[int, ...]] = {
        "embed": (cfg.vocab, d),
        "pos_embed": (cfg.seq_max, d),
        "ln_f.g": (d,),
        "ln_f.b": (d,),
    }
    for i in range(cfg.n_layers):
        shapes[f"l{i}.ln1.g"] = (d,)
        shapes[f"l{i}.ln1.b"] = (d,)
        shapes[f"l{i}.wq"] = (d, h * dh)
        shapes[f"l{i}.wk"] = (d, h * dh)
        shapes[f"l{i}.wv"] = (d, h * dh)
        shapes[f"l{i}.wo"] = (h * dh, d)
        shapes[f"l{i}.ln2.g"] = (d,)
        shapes[f"l{i}.ln2.b"] = (d,)
        shapes[f"l{i}.w1"] = (d, f)
        shapes[f"l{i}.b1"] = (f,)
        shapes[f"l{i}.w2"] = (f, d)
        shapes[f"l{i}.b2"] = (d,)
    if cfg.is_classifier:
        shapes["head.w"] = (d, cfg.n_classes)
        shapes["head.b"] = (cfg.n_classes,)
    else:
        shapes["w_out"] = (d, cfg.vocab)
    return shapes


def init_params(cfg: ModelConfig, seed: int = 0) -> list[jnp.ndarray]:
    """Initialize parameters in canonical order (scaled-normal / ones)."""
    key = jax.random.PRNGKey(seed)
    shapes = param_shapes(cfg)
    out: list[jnp.ndarray] = []
    for name in param_names(cfg):
        shape = shapes[name]
        key, sub = jax.random.split(key)
        if name.endswith(".g"):
            out.append(jnp.ones(shape, jnp.float32))
        elif name.endswith((".b", ".b1", ".b2")) or name == "head.b":
            out.append(jnp.zeros(shape, jnp.float32))
        else:
            out.append(jax.random.normal(sub, shape, jnp.float32) * 0.02)
    return out


def as_dict(cfg: ModelConfig, flat: list[jnp.ndarray]) -> dict[str, jnp.ndarray]:
    names = param_names(cfg)
    assert len(names) == len(flat), f"{len(names)} names vs {len(flat)} params"
    return dict(zip(names, flat))


# ---------------------------------------------------------------------------
# Shared transformer blocks
# ---------------------------------------------------------------------------


def _ops(use_kernels: bool):
    if use_kernels:
        return layernorm, ffn, attention_prefill, attention_encoder
    return ref.layernorm, ref.ffn, ref.attention_prefill, ref.attention_encoder


def _split_heads(x: jnp.ndarray, h: int, dh: int) -> jnp.ndarray:
    b, s, _ = x.shape
    return x.reshape(b, s, h, dh).transpose(0, 2, 1, 3)  # [B,H,S,Dh]


def _merge_heads(x: jnp.ndarray) -> jnp.ndarray:
    b, h, s, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, h * dh)


def _block_full(cfg: ModelConfig, p: dict, i: int, hdn: jnp.ndarray,
                lengths: jnp.ndarray, causal: bool,
                use_kernels: bool) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One pre-LN transformer block over a full [B, S, D] sequence.

    Returns (hidden, k, v) with k/v shaped [B, H, S, Dh] for KV caching.
    """
    ln, mlp, attn_causal, attn_enc = _ops(use_kernels)
    b, s, d = hdn.shape
    flat = hdn.reshape(b * s, d)
    x = ln(flat, p[f"l{i}.ln1.g"], p[f"l{i}.ln1.b"]).reshape(b, s, d)
    q = _split_heads(x @ p[f"l{i}.wq"], cfg.n_heads, cfg.d_head)
    k = _split_heads(x @ p[f"l{i}.wk"], cfg.n_heads, cfg.d_head)
    v = _split_heads(x @ p[f"l{i}.wv"], cfg.n_heads, cfg.d_head)
    attn = attn_causal(q, k, v, lengths) if causal else attn_enc(q, k, v, lengths)
    hdn = hdn + _merge_heads(attn) @ p[f"l{i}.wo"]
    flat = hdn.reshape(b * s, d)
    y = ln(flat, p[f"l{i}.ln2.g"], p[f"l{i}.ln2.b"])
    hdn = hdn + mlp(y, p[f"l{i}.w1"], p[f"l{i}.b1"],
                    p[f"l{i}.w2"], p[f"l{i}.b2"]).reshape(b, s, d)
    return hdn, k, v


# ---------------------------------------------------------------------------
# Decoder LM: prefill + decode step
# ---------------------------------------------------------------------------


def lm_prefill(cfg: ModelConfig, flat_params: list[jnp.ndarray],
               tokens: jnp.ndarray, lengths: jnp.ndarray,
               use_kernels: bool = True):
    """Prefill a padded prompt batch.

    tokens: [B, S] i32 (S = cfg.seq_prefill); lengths: [B] i32.
    Returns (last_logits [B, V], kv [L, 2, B, H, Smax, Dh]).
    The KV cache is padded to seq_max so decode steps can append in place.
    """
    p = as_dict(cfg, flat_params)
    ln, _, _, _ = _ops(use_kernels)
    b, s = tokens.shape
    hdn = p["embed"][tokens] + p["pos_embed"][:s][None]
    ks, vs = [], []
    for i in range(cfg.n_layers):
        hdn, k, v = _block_full(cfg, p, i, hdn, lengths, True, use_kernels)
        pad = cfg.seq_max - s
        ks.append(jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0))))
        vs.append(jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0))))
    kv = jnp.stack([jnp.stack(ks), jnp.stack(vs)], axis=1)  # [L,2,B,H,Smax,Dh]
    flat = hdn.reshape(b * s, cfg.d_model)
    hdn = ln(flat, p["ln_f.g"], p["ln_f.b"]).reshape(b, s, cfg.d_model)
    last = jnp.take_along_axis(
        hdn, (lengths - 1).reshape(b, 1, 1).astype(jnp.int32), axis=1
    )[:, 0]                                                  # [B, D]
    logits = last @ p["w_out"]
    return logits, kv


def _write_kv(cache: jnp.ndarray, new: jnp.ndarray,
              pos: jnp.ndarray) -> jnp.ndarray:
    """Scatter new K or V ([B, H, Dh]) into cache [B, H, Smax, Dh] at pos[b]."""

    def one(c, x, q):
        return jax.lax.dynamic_update_slice(c, x[:, None, :], (0, q, 0))

    return jax.vmap(one)(cache, new, pos)


def lm_decode(cfg: ModelConfig, flat_params: list[jnp.ndarray],
              kv: jnp.ndarray, tokens: jnp.ndarray, pos: jnp.ndarray,
              use_kernels: bool = True):
    """One decode step for a continuous batch.

    kv: [L, 2, B, H, Smax, Dh]; tokens: [B] i32 (this step's inputs);
    pos: [B] i32 per-sequence positions (where this token goes).
    Returns (logits [B, V], kv updated).
    """
    p = as_dict(cfg, flat_params)
    if use_kernels:
        ln, dec = layernorm, attention_decode
    else:
        ln, dec = ref.layernorm, ref.attention_decode
    b = tokens.shape[0]
    hdn = p["embed"][tokens] + p["pos_embed"][pos]           # [B, D]
    new_kv = []
    for i in range(cfg.n_layers):
        x = ln(hdn, p[f"l{i}.ln1.g"], p[f"l{i}.ln1.b"])
        q = (x @ p[f"l{i}.wq"]).reshape(b, cfg.n_heads, cfg.d_head)
        k = (x @ p[f"l{i}.wk"]).reshape(b, cfg.n_heads, cfg.d_head)
        v = (x @ p[f"l{i}.wv"]).reshape(b, cfg.n_heads, cfg.d_head)
        k_cache = _write_kv(kv[i, 0], k, pos)
        v_cache = _write_kv(kv[i, 1], v, pos)
        new_kv.append(jnp.stack([k_cache, v_cache]))
        attn = dec(q, k_cache, v_cache, pos)                 # [B, H, Dh]
        hdn = hdn + attn.reshape(b, -1) @ p[f"l{i}.wo"]
        y = ln(hdn, p[f"l{i}.ln2.g"], p[f"l{i}.ln2.b"])
        if use_kernels:
            hdn = hdn + ffn(y, p[f"l{i}.w1"], p[f"l{i}.b1"],
                            p[f"l{i}.w2"], p[f"l{i}.b2"])
        else:
            hdn = hdn + ref.ffn(y, p[f"l{i}.w1"], p[f"l{i}.b1"],
                                p[f"l{i}.w2"], p[f"l{i}.b2"])
    kv = jnp.stack(new_kv)
    hdn = ln(hdn, p["ln_f.g"], p["ln_f.b"])
    return hdn @ p["w_out"], kv


# ---------------------------------------------------------------------------
# DistilBERT-lite classifier (the Pick router's semantic path)
# ---------------------------------------------------------------------------


def classifier_probs(cfg: ModelConfig, flat_params: list[jnp.ndarray],
                     tokens: jnp.ndarray,
                     use_kernels: bool = True) -> jnp.ndarray:
    """Complexity probabilities (paper Eq. 3/4).

    tokens: [B, S] i32 ([CLS] ... [SEP] PAD...).  Returns [B, 3].
    Lengths are derived from the PAD mask inside the graph so the Rust
    caller only ships token ids.
    """
    p = as_dict(cfg, flat_params)
    ln, _, _, _ = _ops(use_kernels)
    b, s = tokens.shape
    lengths = jnp.sum((tokens != tok.PAD).astype(jnp.int32), axis=1)
    hdn = p["embed"][tokens] + p["pos_embed"][:s][None]
    for i in range(cfg.n_layers):
        hdn, _, _ = _block_full(cfg, p, i, hdn, lengths, False, use_kernels)
    flat = hdn.reshape(b * s, cfg.d_model)
    hdn = ln(flat, p["ln_f.g"], p["ln_f.b"]).reshape(b, s, cfg.d_model)
    h_cls = hdn[:, 0]                                        # [CLS]
    if use_kernels:
        return classifier_head(h_cls, p["head.w"], p["head.b"])
    return ref.classifier_head(h_cls, p["head.w"], p["head.b"])
