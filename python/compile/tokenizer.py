"""Hashed-wordpiece tokenizer shared (bit-for-bit) between Python and Rust.

The serving path never runs Python, so the Rust coordinator re-implements
exactly this algorithm (``rust/src/tokenizer``).  Parity is enforced by
``aot.py`` emitting test vectors (``artifacts/tokenizer_parity.json``) that
both the pytest suite and the cargo test suite check.

Algorithm
---------
1. Lowercase the input.
2. Split into maximal runs of ASCII alphanumeric characters (everything
   else is a separator and is dropped).  Non-ASCII bytes are separators.
3. Each word hashes to an id via FNV-1a 64 over its UTF-8 bytes:
       id = RESERVED + (fnv1a64(word) % (VOCAB - RESERVED))
4. A sequence is ``[CLS] w_1 ... w_n [SEP]`` truncated to ``seq_len`` and
   right-padded with PAD.

The hash vocabulary avoids shipping a learned vocab file while remaining
deterministic and language-agnostic; collisions act like subword sharing.
"""

from __future__ import annotations

VOCAB: int = 4096
PAD: int = 0
CLS: int = 1
SEP: int = 2
UNK: int = 3  # reserved, currently unused (hash never emits it)
RESERVED: int = 4

# Classifier input length; LM contexts use SEQ_PREFILL from model.py.
SEQ_CLS: int = 48

_FNV_OFFSET = 0xCBF29CE484222325
_FNV_PRIME = 0x100000001B3
_MASK64 = 0xFFFFFFFFFFFFFFFF


def fnv1a64(data: bytes) -> int:
    """FNV-1a 64-bit hash (wrapping multiply)."""
    h = _FNV_OFFSET
    for b in data:
        h ^= b
        h = (h * _FNV_PRIME) & _MASK64
    return h


def split_words(text: str) -> list[str]:
    """Lowercase and split into maximal ASCII-alphanumeric runs."""
    out: list[str] = []
    cur: list[str] = []
    for ch in text.lower():
        if ("a" <= ch <= "z") or ("0" <= ch <= "9"):
            cur.append(ch)
        elif cur:
            out.append("".join(cur))
            cur = []
    if cur:
        out.append("".join(cur))
    return out


def word_id(word: str) -> int:
    return RESERVED + fnv1a64(word.encode("utf-8")) % (VOCAB - RESERVED)


def encode(text: str, seq_len: int = SEQ_CLS) -> list[int]:
    """Encode to exactly ``seq_len`` ids: [CLS] words... [SEP] PAD..."""
    ids = [CLS]
    for w in split_words(text)[: seq_len - 2]:
        ids.append(word_id(w))
    ids.append(SEP)
    ids.extend([PAD] * (seq_len - len(ids)))
    return ids[:seq_len]


def encode_words(text: str, max_words: int) -> list[int]:
    """Encode without CLS/SEP framing (LM input): word ids, PAD-padded."""
    ids = [word_id(w) for w in split_words(text)[:max_words]]
    ids.extend([PAD] * (max_words - len(ids)))
    return ids


def valid_len(ids: list[int]) -> int:
    """Number of non-PAD positions (PAD only appears as right padding)."""
    n = len(ids)
    while n > 0 and ids[n - 1] == PAD:
        n -= 1
    return n
