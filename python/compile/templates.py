"""Shared benchmark prompt templates — single source of truth.

The paper evaluates over eight public benchmarks (HumanEval, GSM8K, MBPP,
TruthfulQA, ARC, HellaSwag, MATH, MMLU-Pro).  We cannot ship those datasets,
so we generate synthetic prompts that reproduce the *signals the system
actually consumes*: characteristic task verbs and structure (what the
keyword router keys on), semantic shape (what the DistilBERT-lite classifier
learns), length distributions, and the per-benchmark run counts of Table 1.

This module owns the template data.  ``python -m compile.templates`` dumps
``data/templates.json`` which the Rust workload generator parses at runtime,
so Python (classifier training corpus) and Rust (serving workload) draw from
the same families.

Each template carries its ground-truth complexity class:
  0 = low (fast tier suffices), 1 = medium, 2 = high (reasoning tier).
Some templates are deliberate *confusables* — e.g. a low-complexity prompt
containing the word "prove" — so the keyword router has a realistic error
rate while the semantic classifier can still separate the classes.
"""

from __future__ import annotations

import json
import os

# Slot fillers. Both sides substitute {slot} markers with an item chosen by
# their own seeded RNG — the exact filler does not matter for routing, the
# template's lexical/structural signal does.
SLOTS: dict[str, list[str]] = {
    "num": ["3", "7", "12", "24", "48", "96", "150", "365", "1024"],
    "num2": ["2", "5", "8", "15", "30", "60", "81", "256"],
    "item": ["apples", "marbles", "tickets", "pages", "coins", "stickers",
             "bottles", "pencils", "cookies", "stamps"],
    "name": ["natalia", "james", "maria", "wei", "amara", "diego", "yuki",
             "fatima", "oliver", "priya"],
    "topic": ["photosynthesis", "plate tectonics", "supply and demand",
              "binary search", "the water cycle", "electromagnetism",
              "natural selection", "the french revolution", "queueing theory",
              "byzantine fault tolerance"],
    "claim": ["humans use only ten percent of their brains",
              "lightning never strikes the same place twice",
              "goldfish have a three second memory",
              "the great wall is visible from space",
              "cracking knuckles causes arthritis",
              "bulls are enraged by the color red"],
    "field": ["biology", "economics", "physics", "law", "computer science",
              "chemistry", "psychology", "engineering", "history",
              "statistics"],
    "task": ["reverses a linked list", "checks if a string is a palindrome",
             "merges two sorted arrays", "computes the nth fibonacci number",
             "finds duplicates in a list", "parses a csv line",
             "flattens a nested dictionary", "validates an email address",
             "computes a running median", "topologically sorts a dag"],
    "activity": ["fixing a bicycle tire", "baking sourdough bread",
                 "planting tomato seedlings", "changing a car battery",
                 "setting up a tent", "icing a cake"],
    "adj": ["continuous", "bounded", "monotonic", "convex", "symmetric",
            "irrational"],
    "obj": ["function", "sequence", "matrix", "polynomial", "graph", "set"],
}

# (complexity, template) pairs per benchmark. Complexity 0/1/2.
_B = {
    "humaneval": [
        (1, "write a python function that {task}."),
        (1, "implement a function which {task} and return the result."),
        (2, "write a python function that {task}, then explain why your "
            "solution runs in optimal asymptotic time."),
        (2, "design and implement an efficient algorithm that {task}; "
            "analyze its worst case complexity step by step."),
        (1, "complete the following code so that it {task}."),
        (0, "define a python function named helper that returns {num}."),
    ],
    "gsm8k": [
        (1, "{name} sold {num} {item} in april and {num2} fewer in may. "
            "how many {item} did {name} sell in total?"),
        (1, "a box holds {num} {item}. {name} buys {num2} boxes and gives "
            "away {num} {item}. how many {item} remain?"),
        (1, "{name} reads {num} {item} per day. how many {item} after "
            "{num2} days?"),
        (2, "{name} invests {num} dollars at {num2} percent compounded "
            "yearly. derive the balance after {num} years, reasoning step "
            "by step."),
        (0, "what is {num} plus {num2}?"),
        (0, "compute the sum of {num} and {num2}."),
    ],
    "mbpp": [
        (1, "write a function to remove duplicate {item} from a list."),
        (1, "write a python program that {task}."),
        (0, "write a one line python expression that returns the sum of "
            "{num} and {num2}."),
        (1, "given a list of integers, write code that {task}."),
        (2, "write a python function that {task}; prove that it terminates "
            "on every input."),
    ],
    "truthfulqa": [
        (0, "is it true that {claim}?"),
        (1, "is it true that {claim}? justify your answer briefly."),
        (2, "many people believe {claim}. explain why this belief is "
            "mistaken and what the evidence actually shows."),
        (0, "true or false: {claim}."),
        (1, "what do experts say about the claim that {claim}?"),
    ],
    "arc": [
        (0, "which of the following best describes {topic}? a, b, c or d."),
        (0, "name the process by which plants make food."),
        (1, "a student observes {topic} in the lab. which hypothesis best "
            "explains the observation?"),
        (1, "why does {topic} occur more rapidly at higher temperatures?"),
        (2, "design an experiment to distinguish between two competing "
            "explanations of {topic}, and explain why each control is "
            "necessary."),
    ],
    "hellaswag": [
        (0, "{name} is {activity}. what happens next?"),
        (0, "a person starts {activity}. choose the most likely "
            "continuation."),
        (0, "finish the sentence: {name} picked up the {item} and"),
        (1, "{name} is {activity} while talking about {topic}. what is the "
            "most plausible next step and why?"),
    ],
    "math": [
        (2, "prove that the {obj} defined by f(n) = {num}n + {num2} is "
            "{adj} for all natural numbers n."),
        (2, "derive a closed form for the sum of the first {num} odd "
            "numbers and prove it by induction."),
        (2, "let f be a {adj} {obj}. show that f attains its maximum on "
            "any closed interval."),
        (1, "solve for x: {num}x + {num2} = {num}."),
        (1, "find the greatest common divisor of {num} and {num2}."),
        (0, "what is {num} times {num2}?"),
        (2, "explain why every {adj} {obj} of degree {num2} has at most "
            "{num2} real roots, step by step."),
    ],
    "mmlu_pro": [
        (1, "in {field}, which statement about {topic} is correct?"),
        (1, "a practitioner of {field} encounters {topic}. what is the "
            "standard approach?"),
        (2, "compare and contrast two theories of {topic} in {field}, and "
            "analyze which better explains the empirical evidence."),
        (0, "define the term {topic} as used in {field}."),
        (0, "list the main branches of {field}."),
        (2, "explain why {topic} matters in {field} and derive its key "
            "quantitative relationship."),
        (1, "which of the following is an example of {topic}? a, b, c, d "
            "or e."),
    ],
}

# Table 1 of the paper: per-benchmark runs and baseline successes.
# (The paper's printed total row, 163,720, does not equal the column sum of
# 155,095 — we reproduce the per-benchmark rows exactly and note the
# discrepancy in EXPERIMENTS.md.)
TABLE1 = {
    "humaneval": {"runs": 820, "success": 656},
    "gsm8k": {"runs": 6595, "success": 5924},
    "mbpp": {"runs": 2500, "success": 1736},
    "truthfulqa": {"runs": 3950, "success": 3167},
    "arc": {"runs": 5860, "success": 4704},
    "hellaswag": {"runs": 50210, "success": 40260},
    "math": {"runs": 25000, "success": 19908},
    "mmlu_pro": {"runs": 60160, "success": 42103},
}

# Five inference profiles per prompt (baseline + 4 operator profiles)
PROFILES = ["baseline", "quality", "cost", "speed", "balanced"]

BENCHMARKS = list(_B.keys())


def benchmark_templates(name: str) -> list[tuple[int, str]]:
    return _B[name]


def unique_prompts(name: str) -> int:
    """Unique prompt count = Table 1 runs / 5 profiles (paper: 31,019)."""
    return TABLE1[name]["runs"] // len(PROFILES)


def as_json() -> dict:
    return {
        "slots": SLOTS,
        "benchmarks": [
            {
                "name": b,
                "runs": TABLE1[b]["runs"],
                "success": TABLE1[b]["success"],
                "unique_prompts": unique_prompts(b),
                "templates": [
                    {"complexity": c, "text": t} for (c, t) in _B[b]
                ],
            }
            for b in BENCHMARKS
        ],
        "profiles": PROFILES,
    }


def dump(path: str) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(as_json(), f, indent=1, sort_keys=True)


if __name__ == "__main__":
    import sys

    out = sys.argv[1] if len(sys.argv) > 1 else "../data/templates.json"
    dump(out)
    print(f"wrote {out}")
