"""Synthetic training corpus for the complexity classifier.

Generates the same number of unique prompts per benchmark as the paper
(31,019 total = Table 1 runs / 5 profiles), labeled with the template's
ground-truth complexity class.  A deterministic SplitMix64 stream drives
template and slot selection so the corpus is reproducible bit-for-bit.
"""

from __future__ import annotations

from dataclasses import dataclass

from . import templates as T

_MASK64 = 0xFFFFFFFFFFFFFFFF


class SplitMix64:
    """Deterministic 64-bit stream; mirrored in rust/src/util/rng.rs."""

    def __init__(self, seed: int):
        self.state = seed & _MASK64

    def next_u64(self) -> int:
        self.state = (self.state + 0x9E3779B97F4A7C15) & _MASK64
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & _MASK64
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & _MASK64
        return z ^ (z >> 31)

    def below(self, n: int) -> int:
        return self.next_u64() % n


@dataclass
class Prompt:
    benchmark: str
    text: str
    complexity: int  # 0 low, 1 medium, 2 high


def fill(template: str, rng: SplitMix64) -> str:
    """Substitute every {slot} with a filler chosen by ``rng``."""
    out: list[str] = []
    i = 0
    while i < len(template):
        ch = template[i]
        if ch == "{":
            j = template.index("}", i)
            slot = template[i + 1 : j]
            fillers = T.SLOTS[slot]
            out.append(fillers[rng.below(len(fillers))])
            i = j + 1
        else:
            out.append(ch)
            i += 1
    return "".join(out)


def generate(seed: int = 0x5EED_CAFE) -> list[Prompt]:
    """All unique prompts across the eight benchmarks (paper: 31,019)."""
    prompts: list[Prompt] = []
    for b in T.BENCHMARKS:
        rng = SplitMix64(seed ^ hash_name(b))
        tpls = T.benchmark_templates(b)
        for _ in range(T.unique_prompts(b)):
            c, t = tpls[rng.below(len(tpls))]
            prompts.append(Prompt(b, fill(t, rng), c))
    return prompts


def hash_name(name: str) -> int:
    """FNV-1a 64 of the benchmark name (stable across sessions)."""
    h = 0xCBF29CE484222325
    for byte in name.encode():
        h ^= byte
        h = (h * 0x100000001B3) & _MASK64
    return h


def train_val_split(
    prompts: list[Prompt], val_frac: float = 0.1, seed: int = 1234
) -> tuple[list[Prompt], list[Prompt]]:
    """Deterministic shuffle then split (paper: 10% held-out)."""
    rng = SplitMix64(seed)
    idx = list(range(len(prompts)))
    for i in range(len(idx) - 1, 0, -1):  # Fisher-Yates
        j = rng.below(i + 1)
        idx[i], idx[j] = idx[j], idx[i]
    n_val = int(len(prompts) * val_frac)
    val = [prompts[i] for i in idx[:n_val]]
    train = [prompts[i] for i in idx[n_val:]]
    return train, val


if __name__ == "__main__":
    ps = generate()
    from collections import Counter

    print(f"{len(ps)} prompts")
    print(Counter(p.benchmark for p in ps))
    print(Counter(p.complexity for p in ps))
