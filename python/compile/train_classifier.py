"""Train the DistilBERT-lite complexity classifier (build-time only).

The paper fine-tunes DistilBERT for 3-way complexity classification with
AdamW (batch 32, lr 2e-5, 100 epochs) reaching 96.8% on a 10% held-out
split of 31,019 prompts.  We train our DistilBERT-lite on the synthetic
corpus of the same size/split with a hand-rolled AdamW (no optax in the
image) and target >= 95% validation accuracy — ``aot.py`` refuses to
export a router classifier below ``MIN_VAL_ACC``.

Training runs through the *reference* (pure-jnp) forward because
``pallas_call`` defines no VJP; pytest asserts kernel==ref agreement so
the exported kernel-backed HLO serves the same function.
"""

from __future__ import annotations

import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus
from . import model as M
from . import tokenizer as tok

MIN_VAL_ACC = 0.95


@dataclass
class TrainResult:
    params: list[jnp.ndarray]
    val_accuracy: float
    train_accuracy: float
    steps: int
    seconds: float


def _encode_batch(prompts: list[corpus.Prompt]) -> tuple[np.ndarray, np.ndarray]:
    x = np.zeros((len(prompts), tok.SEQ_CLS), np.int32)
    y = np.zeros((len(prompts),), np.int32)
    for i, p in enumerate(prompts):
        x[i] = tok.encode(p.text, tok.SEQ_CLS)
        y[i] = p.complexity
    return x, y


def _loss_fn(flat_params, tokens, labels):
    probs = M.classifier_probs(M.CLASSIFIER, list(flat_params), tokens,
                               use_kernels=False)
    logp = jnp.log(probs + 1e-9)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=1).mean()
    return nll, probs


def _adamw_update(params, grads, m, v, step, lr, wd=0.01,
                  b1=0.9, b2=0.999, eps=1e-8):
    """One AdamW step over flat parameter lists."""
    new_p, new_m, new_v = [], [], []
    t = step.astype(jnp.float32) + 1.0
    for p, g, mi, vi in zip(params, grads, m, v):
        mi = b1 * mi + (1 - b1) * g
        vi = b2 * vi + (1 - b2) * g * g
        mhat = mi / (1 - b1**t)
        vhat = vi / (1 - b2**t)
        p = p - lr * (mhat / (jnp.sqrt(vhat) + eps) + wd * p)
        new_p.append(p)
        new_m.append(mi)
        new_v.append(vi)
    return new_p, new_m, new_v


def accuracy(params: list[jnp.ndarray], x: np.ndarray, y: np.ndarray,
             batch: int = 256, use_kernels: bool = False) -> float:
    """Classification accuracy, evaluated in fixed-size padded batches."""
    fwd = jax.jit(
        lambda ps, t: M.classifier_probs(M.CLASSIFIER, ps, t, use_kernels)
    )
    hits = 0
    for i in range(0, len(x), batch):
        xb, yb = x[i : i + batch], y[i : i + batch]
        n = len(xb)
        if n < batch:  # pad to the jitted shape, ignore the padding rows
            xb = np.pad(xb, ((0, batch - n), (0, 0)))
        pred = np.argmax(np.asarray(fwd(params, jnp.asarray(xb))), axis=1)[:n]
        hits += int((pred == yb).sum())
    return hits / len(x)


def train(seed: int = 0, batch: int = 64, lr: float = 3e-4,
          epochs: int = 2, log=print) -> TrainResult:
    t0 = time.time()
    prompts = corpus.generate()
    train_ps, val_ps = corpus.train_val_split(prompts, val_frac=0.1)
    x_tr, y_tr = _encode_batch(train_ps)
    x_va, y_va = _encode_batch(val_ps)
    log(f"corpus: {len(train_ps)} train / {len(val_ps)} val prompts")

    params = M.init_params(M.CLASSIFIER, seed)
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]

    grad_fn = jax.value_and_grad(_loss_fn, has_aux=True)

    @jax.jit
    def step_fn(params, m, v, step, tokens, labels):
        (loss, _), grads = grad_fn(params, tokens, labels)
        params, m, v = _adamw_update(params, grads, m, v, step, lr)
        return params, m, v, loss

    rng = corpus.SplitMix64(seed ^ 0xA11CE)
    n = len(x_tr)
    steps = 0
    for epoch in range(epochs):
        order = np.arange(n)
        # Fisher-Yates with the shared deterministic stream
        for i in range(n - 1, 0, -1):
            j = rng.below(i + 1)
            order[i], order[j] = order[j], order[i]
        losses = []
        for i in range(0, n - batch + 1, batch):
            idx = order[i : i + batch]
            params, m, v, loss = step_fn(
                params, m, v, jnp.asarray(steps),
                jnp.asarray(x_tr[idx]), jnp.asarray(y_tr[idx]),
            )
            losses.append(float(loss))
            steps += 1
        log(f"epoch {epoch}: mean loss {np.mean(losses):.4f}")

    val_acc = accuracy(params, x_va, y_va)
    tr_acc = accuracy(params, x_tr[:4096], y_tr[:4096])
    log(f"train acc {tr_acc:.4f}  val acc {val_acc:.4f} "
        f"({time.time() - t0:.1f}s, {steps} steps)")
    return TrainResult(params, val_acc, tr_acc, steps, time.time() - t0)


if __name__ == "__main__":
    train()
