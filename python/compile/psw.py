"""PSW — the Pick-and-Spin weight container (build-time writer).

A deliberately trivial binary tensor format shared with the Rust loader
(``rust/src/runtime/weights.rs``); we cannot ship safetensors/npz offline
and HLO-text constants would bloat the interchange files, so weights are
runtime inputs stored here.

Layout (little-endian):
    magic   b"PSW1"
    u32     tensor count
    repeat:
        u16     name length, then name (utf-8)
        u8      dtype (0 = f32, 1 = i32)
        u8      ndim
        u32[n]  dims
        bytes   row-major data
"""

from __future__ import annotations

import struct

import numpy as np

MAGIC = b"PSW1"
DTYPE_F32 = 0
DTYPE_I32 = 1


def write(path: str, tensors: list[tuple[str, np.ndarray]]) -> int:
    """Write named tensors; returns total bytes."""
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(tensors)))
        for name, arr in tensors:
            arr = np.ascontiguousarray(arr)
            if arr.dtype == np.float32:
                dt = DTYPE_F32
            elif arr.dtype == np.int32:
                dt = DTYPE_I32
            else:
                raise ValueError(f"{name}: unsupported dtype {arr.dtype}")
            nb = name.encode("utf-8")
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", dt, arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(arr.tobytes())
        return f.tell()


def read(path: str) -> list[tuple[str, np.ndarray]]:
    """Read back (for round-trip tests)."""
    out: list[tuple[str, np.ndarray]] = []
    with open(path, "rb") as f:
        assert f.read(4) == MAGIC, "bad magic"
        (count,) = struct.unpack("<I", f.read(4))
        for _ in range(count):
            (nlen,) = struct.unpack("<H", f.read(2))
            name = f.read(nlen).decode("utf-8")
            dt, ndim = struct.unpack("<BB", f.read(2))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim)) if ndim else ()
            np_dt = np.float32 if dt == DTYPE_F32 else np.int32
            n = int(np.prod(dims)) if dims else 1
            data = np.frombuffer(f.read(4 * n), np_dt).reshape(dims)
            out.append((name, data))
    return out
