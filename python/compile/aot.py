"""AOT pipeline: lower every serving computation to HLO text + weights.

Run once at build time (``make artifacts``); the Rust coordinator is
self-contained afterwards.  Outputs under ``artifacts/``:

* ``<module>.hlo.txt``      — HLO text per compiled computation
  (classifier at batch 1/8; per LM tier: prefill at batch 1/4 and decode
  at batch 1/4/8).  HLO *text*, not serialized protos: jax >= 0.5 emits
  64-bit instruction ids that xla_extension 0.5.1 rejects; the text
  parser reassigns ids (see /opt/xla-example/README.md).
* ``<model>.psw``           — weights as runtime inputs (see psw.py).
* ``manifest.json``         — module inventory: input/output specs in the
  exact positional order the Rust runtime must feed PJRT.
* ``tokenizer_parity.json`` — cross-language tokenizer test vectors.
* ``../data/templates.json``— shared benchmark templates for the Rust
  workload generator.

The complexity classifier is *trained* here (paper: DistilBERT fine-tuned
to 96.8% val acc; gate: >= 95%).  LM tier weights are seeded-random — the
serving system's behaviour depends on latency/cost/shape, not on text
quality (DESIGN.md §Substitutions).
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from . import psw
from . import templates
from . import tokenizer as tok
from .train_classifier import MIN_VAL_ACC, train

PREFILL_BATCHES = [1, 4]
DECODE_BATCHES = [1, 4, 8]
CLASSIFIER_BATCHES = [1, 8]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _param_specs(cfg: M.ModelConfig) -> list[jax.ShapeDtypeStruct]:
    shapes = M.param_shapes(cfg)
    return [
        jax.ShapeDtypeStruct(shapes[n], jnp.float32)
        for n in M.param_names(cfg)
    ]


def _weight_inputs(cfg: M.ModelConfig) -> list[dict]:
    shapes = M.param_shapes(cfg)
    return [
        {"kind": "weight", "name": n, "dtype": "f32",
         "shape": list(shapes[n])}
        for n in M.param_names(cfg)
    ]


def lower_classifier(cfg: M.ModelConfig, batch: int) -> str:
    def fn(*args):
        *params, tokens = args
        return (M.classifier_probs(cfg, list(params), tokens, True),)

    specs = _param_specs(cfg) + [
        jax.ShapeDtypeStruct((batch, cfg.seq_prefill), jnp.int32)
    ]
    return to_hlo_text(jax.jit(fn).lower(*specs))


def lower_prefill(cfg: M.ModelConfig, batch: int) -> str:
    def fn(*args):
        *params, tokens, lengths = args
        return M.lm_prefill(cfg, list(params), tokens, lengths, True)

    specs = _param_specs(cfg) + [
        jax.ShapeDtypeStruct((batch, cfg.seq_prefill), jnp.int32),
        jax.ShapeDtypeStruct((batch,), jnp.int32),
    ]
    return to_hlo_text(jax.jit(fn).lower(*specs))


def lower_decode(cfg: M.ModelConfig, batch: int) -> str:
    def fn(*args):
        *params, kv, tokens, pos = args
        return M.lm_decode(cfg, list(params), kv, tokens, pos, True)

    kv_shape = (cfg.n_layers, 2, batch, cfg.n_heads, cfg.seq_max, cfg.d_head)
    specs = _param_specs(cfg) + [
        jax.ShapeDtypeStruct(kv_shape, jnp.float32),
        jax.ShapeDtypeStruct((batch,), jnp.int32),
        jax.ShapeDtypeStruct((batch,), jnp.int32),
    ]
    return to_hlo_text(jax.jit(fn).lower(*specs))


def kv_shape(cfg: M.ModelConfig, batch: int) -> list[int]:
    return [cfg.n_layers, 2, batch, cfg.n_heads, cfg.seq_max, cfg.d_head]


def parity_vectors() -> dict:
    """Tokenizer test vectors checked by BOTH pytest and cargo test."""
    texts = [
        "What is 2 plus 2?",
        "Prove that the function f(n) = 3n + 7 is monotonic.",
        "write a python function that reverses a linked list.",
        "Ünïcödé   mixed WITH caps & punct!!! 123abc",
        "",
        "a",
        " ".join(["word"] * 100),  # truncation case
    ]
    return {
        "vocab": tok.VOCAB,
        "seq_cls": tok.SEQ_CLS,
        "cases": [
            {"text": t, "ids": tok.encode(t, tok.SEQ_CLS)} for t in texts
        ],
        "word_ids": {w: tok.word_id(w) for w in
                     ["sum", "prove", "derive", "list", "define", "the",
                      "photosynthesis", "123abc"]},
    }


def build(out_dir: str, data_dir: str, seed: int, retrain: bool,
          quick: bool) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    os.makedirs(data_dir, exist_ok=True)

    templates.dump(os.path.join(data_dir, "templates.json"))
    with open(os.path.join(out_dir, "tokenizer_parity.json"), "w") as f:
        json.dump(parity_vectors(), f, indent=1)

    modules: list[dict] = []
    models: dict[str, dict] = {}

    # ----- classifier (trained) -----
    print("== training classifier ==", flush=True)
    epochs = 1 if quick else 2
    result = train(seed=seed, epochs=epochs)
    if result.val_accuracy < MIN_VAL_ACC and not quick:
        sys.exit(
            f"classifier val acc {result.val_accuracy:.4f} < {MIN_VAL_ACC}"
        )
    ccfg = M.CLASSIFIER
    cls_params = [np.asarray(p) for p in result.params]
    psw.write(os.path.join(out_dir, "classifier.psw"),
              list(zip(M.param_names(ccfg), cls_params)))
    models["classifier"] = {
        "weights": "classifier.psw",
        "config": ccfg.__dict__,
        "param_count": int(sum(p.size for p in cls_params)),
        "val_accuracy": result.val_accuracy,
        "train_accuracy": result.train_accuracy,
    }
    for b in CLASSIFIER_BATCHES:
        name = f"classifier_b{b}"
        print(f"== lowering {name} ==", flush=True)
        with open(os.path.join(out_dir, f"{name}.hlo.txt"), "w") as f:
            f.write(lower_classifier(ccfg, b))
        modules.append({
            "name": name, "kind": "classifier", "model": "classifier",
            "batch": b,
            "hlo": f"{name}.hlo.txt",
            "inputs": _weight_inputs(ccfg) + [
                {"kind": "tokens", "dtype": "i32",
                 "shape": [b, ccfg.seq_prefill]},
            ],
            "outputs": [{"kind": "probs", "dtype": "f32",
                         "shape": [b, ccfg.n_classes]}],
        })

    # ----- LM tiers (seeded-random weights) -----
    for tier, cfg in M.TIERS.items():
        params = [np.asarray(p) for p in M.init_params(cfg, seed + hash_tier(tier))]
        psw.write(os.path.join(out_dir, f"lm_{tier}.psw"),
                  list(zip(M.param_names(cfg), params)))
        models[tier] = {
            "weights": f"lm_{tier}.psw",
            "config": cfg.__dict__,
            "param_count": int(sum(p.size for p in params)),
        }
        for b in PREFILL_BATCHES:
            name = f"lm_{tier}_prefill_b{b}"
            print(f"== lowering {name} ==", flush=True)
            with open(os.path.join(out_dir, f"{name}.hlo.txt"), "w") as f:
                f.write(lower_prefill(cfg, b))
            modules.append({
                "name": name, "kind": "prefill", "model": tier, "batch": b,
                "hlo": f"{name}.hlo.txt",
                "inputs": _weight_inputs(cfg) + [
                    {"kind": "tokens", "dtype": "i32",
                     "shape": [b, cfg.seq_prefill]},
                    {"kind": "lengths", "dtype": "i32", "shape": [b]},
                ],
                "outputs": [
                    {"kind": "logits", "dtype": "f32",
                     "shape": [b, cfg.vocab]},
                    {"kind": "kv", "dtype": "f32", "shape": kv_shape(cfg, b)},
                ],
            })
        for b in DECODE_BATCHES:
            name = f"lm_{tier}_decode_b{b}"
            print(f"== lowering {name} ==", flush=True)
            with open(os.path.join(out_dir, f"{name}.hlo.txt"), "w") as f:
                f.write(lower_decode(cfg, b))
            modules.append({
                "name": name, "kind": "decode", "model": tier, "batch": b,
                "hlo": f"{name}.hlo.txt",
                "inputs": _weight_inputs(cfg) + [
                    {"kind": "kv", "dtype": "f32", "shape": kv_shape(cfg, b)},
                    {"kind": "tokens", "dtype": "i32", "shape": [b]},
                    {"kind": "pos", "dtype": "i32", "shape": [b]},
                ],
                "outputs": [
                    {"kind": "logits", "dtype": "f32",
                     "shape": [b, cfg.vocab]},
                    {"kind": "kv", "dtype": "f32", "shape": kv_shape(cfg, b)},
                ],
            })

    manifest = {
        "format": 1,
        "tokenizer": {"vocab": tok.VOCAB, "seq_cls": tok.SEQ_CLS,
                      "pad": tok.PAD, "cls": tok.CLS, "sep": tok.SEP},
        "models": models,
        "modules": modules,
        "complexity_classes": ["low", "medium", "high"],
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(modules)} modules to {out_dir}")
    return manifest


def hash_tier(name: str) -> int:
    return sum(name.encode()) % 1000


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--data", default="../data")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--retrain", action="store_true")
    ap.add_argument("--quick", action="store_true",
                    help="1 training epoch, skip accuracy gate (CI smoke)")
    args = ap.parse_args()
    build(args.out, args.data, args.seed, args.retrain, args.quick)


if __name__ == "__main__":
    main()
