"""Training-loop unit tests (AdamW math, loss behaviour) — fast, no corpus."""

import jax
import jax.numpy as jnp
import numpy as np

from compile.train_classifier import _adamw_update, _loss_fn
from compile import model as M


def test_adamw_reduces_quadratic_loss():
    # Minimize ||p - target||^2 with the hand-rolled AdamW.
    target = jnp.asarray([3.0, -2.0, 0.5])
    p = [jnp.zeros(3)]
    m = [jnp.zeros(3)]
    v = [jnp.zeros(3)]
    for step in range(300):
        g = [2 * (p[0] - target)]
        p, m, v = _adamw_update(p, g, m, v, jnp.asarray(step), lr=0.05, wd=0.0)
    np.testing.assert_allclose(np.asarray(p[0]), np.asarray(target), atol=1e-2)


def test_adamw_weight_decay_shrinks_params():
    p = [jnp.ones(4) * 10.0]
    m = [jnp.zeros(4)]
    v = [jnp.zeros(4)]
    g = [jnp.zeros(4)]
    p2, _, _ = _adamw_update(p, g, m, v, jnp.asarray(0), lr=0.1, wd=0.5)
    assert float(p2[0][0]) < 10.0


def test_bias_correction_first_step():
    # With b1=0.9, the bias-corrected first step should move ~lr in the
    # gradient direction, not lr*(1-b1).
    p = [jnp.zeros(1)]
    m = [jnp.zeros(1)]
    v = [jnp.zeros(1)]
    g = [jnp.ones(1)]
    p2, _, _ = _adamw_update(p, g, m, v, jnp.asarray(0), lr=0.1, wd=0.0)
    assert abs(float(p2[0][0]) + 0.1) < 1e-3


def test_loss_decreases_on_tiny_problem():
    cfg = M.ModelConfig("t", 64, 16, 1, 2, 8, 32, 12, 12, n_classes=3)
    params = M.init_params(cfg, 0)

    def loss_fn(ps, toks, ys):
        probs = M.classifier_probs(cfg, list(ps), toks, use_kernels=False)
        return -jnp.log(
            jnp.take_along_axis(probs, ys[:, None], axis=1) + 1e-9
        ).mean()

    rs = np.random.RandomState(0)
    toks = jnp.asarray(rs.randint(4, 64, size=(32, 12)), jnp.int32)
    ys = jnp.asarray(rs.randint(0, 3, size=32), jnp.int32)
    grad = jax.jit(jax.value_and_grad(loss_fn))
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    l0, _ = grad(params, toks, ys)
    for step in range(30):
        loss, g = grad(params, toks, ys)
        params, m, v = _adamw_update(params, g, m, v, jnp.asarray(step), 1e-3)
    l1, _ = grad(params, toks, ys)
    assert float(l1) < float(l0) * 0.8


def test_loss_fn_matches_cross_entropy():
    params = M.init_params(M.CLASSIFIER, 0)
    toks = jnp.ones((4, M.CLASSIFIER.seq_prefill), jnp.int32)
    ys = jnp.asarray([0, 1, 2, 0], jnp.int32)
    nll, probs = _loss_fn(params, toks, ys)
    manual = -np.mean(
        [np.log(np.asarray(probs)[i, int(ys[i])] + 1e-9) for i in range(4)]
    )
    assert abs(float(nll) - manual) < 1e-5
