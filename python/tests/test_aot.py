"""AOT artifact contract: manifest consistency, psw round-trip, HLO text."""

import json
import os

import numpy as np
import pytest

from compile import model as M
from compile import psw

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

pytestmark = pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)


@pytest.fixture(scope="module")
def manifest():
    with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
        return json.load(f)


def test_all_modules_present(manifest):
    names = {m["name"] for m in manifest["modules"]}
    for tier in ("small", "medium", "large"):
        assert f"lm_{tier}_prefill_b1" in names
        assert f"lm_{tier}_decode_b1" in names
        assert f"lm_{tier}_decode_b8" in names
    assert "classifier_b1" in names


def test_hlo_files_exist_and_are_text(manifest):
    for m in manifest["modules"]:
        path = os.path.join(ARTIFACTS, m["hlo"])
        assert os.path.exists(path), m["hlo"]
        with open(path) as f:
            head = f.read(200)
        assert "HloModule" in head, f"{m['hlo']} is not HLO text"


def test_psw_roundtrip(manifest):
    for model, info in manifest["models"].items():
        path = os.path.join(ARTIFACTS, info["weights"])
        tensors = psw.read(path)
        total = sum(int(np.prod(a.shape)) if a.shape else 1 for _, a in tensors)
        assert total == info["param_count"]
        # order must match the canonical param order
        cfg_d = dict(info["config"])
        cfg = M.ModelConfig(**cfg_d)
        assert [n for n, _ in tensors] == M.param_names(cfg)


def test_input_order_weights_first(manifest):
    for m in manifest["modules"]:
        kinds = [i["kind"] for i in m["inputs"]]
        n_w = sum(1 for k in kinds if k == "weight")
        assert all(k == "weight" for k in kinds[:n_w])
        assert all(k != "weight" for k in kinds[n_w:])


def test_decode_io_shapes_consistent(manifest):
    for m in manifest["modules"]:
        if m["kind"] != "decode":
            continue
        kv_in = [i for i in m["inputs"] if i["kind"] == "kv"][0]
        kv_out = [o for o in m["outputs"] if o["kind"] == "kv"][0]
        assert kv_in["shape"] == kv_out["shape"]
        b = m["batch"]
        toks = [i for i in m["inputs"] if i["kind"] == "tokens"][0]
        assert toks["shape"] == [b]
        assert kv_in["shape"][2] == b


def test_classifier_accuracy_recorded(manifest):
    acc = manifest["models"]["classifier"]["val_accuracy"]
    assert acc >= 0.95  # the paper reports 96.8%


def test_trained_classifier_separates_complexity():
    """Weights from artifacts must route obvious prompts correctly."""
    import jax.numpy as jnp

    from compile import tokenizer as tok

    tensors = psw.read(os.path.join(ARTIFACTS, "classifier.psw"))
    params = [jnp.asarray(a) for _, a in tensors]
    cases = [
        ("what is 7 plus 3?", 0),
        ("prove that the sequence defined by f(n) = 3n + 7 is monotonic "
         "for all natural numbers n.", 2),
    ]
    ids = jnp.asarray([tok.encode(t) for t, _ in cases], jnp.int32)
    probs = M.classifier_probs(M.CLASSIFIER, params, ids, use_kernels=True)
    preds = np.argmax(np.asarray(probs), axis=1)
    assert preds[0] == 0
    assert preds[1] == 2
