"""Tokenizer unit tests + the cross-language parity contract."""

import json
import os

import pytest

# Property sweeps need hypothesis; offline dev boxes may lack it, so the
# whole module is skipped (not errored) there. CI installs hypothesis and
# runs these for real.
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from compile import tokenizer as tok

settings.register_profile("tok", deadline=None, max_examples=100)
settings.load_profile("tok")

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_fnv_known_vectors():
    # Standard FNV-1a 64 test vectors
    assert tok.fnv1a64(b"") == 0xCBF29CE484222325
    assert tok.fnv1a64(b"a") == 0xAF63DC4C8601EC8C
    assert tok.fnv1a64(b"foobar") == 0x85944171F73967E8


def test_split_words():
    assert tok.split_words("Hello, World!") == ["hello", "world"]
    assert tok.split_words("f(n) = 3n + 7") == ["f", "n", "3n", "7"]
    assert tok.split_words("") == []
    assert tok.split_words("  ... !!! ") == []
    assert tok.split_words("Ünïcödé") == ["n", "c", "d"]  # non-ascii splits


def test_encode_framing():
    ids = tok.encode("hello world", 8)
    assert ids[0] == tok.CLS
    assert ids[3] == tok.SEP
    assert ids[4:] == [tok.PAD] * 4
    assert len(ids) == 8


def test_encode_truncation():
    ids = tok.encode(" ".join(["w"] * 100), 16)
    assert len(ids) == 16
    assert ids[0] == tok.CLS
    assert tok.PAD not in ids  # full


def test_empty_prompt():
    ids = tok.encode("", 8)
    assert ids == [tok.CLS, tok.SEP] + [tok.PAD] * 6


@given(st.text(max_size=300))
def test_encode_always_well_formed(text):
    ids = tok.encode(text, tok.SEQ_CLS)
    assert len(ids) == tok.SEQ_CLS
    assert ids[0] == tok.CLS
    assert all(0 <= i < tok.VOCAB for i in ids)
    # PAD appears only as a suffix
    n = tok.valid_len(ids)
    assert all(i != tok.PAD for i in ids[:n])
    assert all(i == tok.PAD for i in ids[n:])


@given(st.text(max_size=200))
def test_ids_never_reserved_except_framing(text):
    ids = tok.encode(text, tok.SEQ_CLS)
    body = [i for i in ids[1:] if i not in (tok.PAD, tok.SEP)]
    assert all(i >= tok.RESERVED for i in body)


@given(st.text(max_size=200))
def test_deterministic(text):
    assert tok.encode(text) == tok.encode(text)


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "tokenizer_parity.json")),
    reason="artifacts not built",
)
def test_parity_vectors_match_artifacts():
    """The vectors cargo test checks must match what this code produces."""
    with open(os.path.join(ARTIFACTS, "tokenizer_parity.json")) as f:
        vec = json.load(f)
    assert vec["vocab"] == tok.VOCAB
    for case in vec["cases"]:
        assert tok.encode(case["text"], tok.SEQ_CLS) == case["ids"]
    for w, i in vec["word_ids"].items():
        assert tok.word_id(w) == i
