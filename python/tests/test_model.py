"""L2 invariants: kernel/ref agreement, KV-cache equivalence, causality."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import tokenizer as tok


def small_cfg():
    # An extra-small config so tests run fast; same code path as the tiers.
    return M.ModelConfig("test", 256, 32, 2, 2, 16, 64, 16, 24)


@pytest.fixture(scope="module")
def cfg():
    return small_cfg()


@pytest.fixture(scope="module")
def params(cfg):
    return M.init_params(cfg, 7)


def toks(cfg, lengths, seed=0):
    rs = np.random.RandomState(seed)
    b = len(lengths)
    t = np.zeros((b, cfg.seq_prefill), np.int32)
    for i, L in enumerate(lengths):
        t[i, :L] = rs.randint(4, cfg.vocab, size=L)
    return jnp.asarray(t), jnp.asarray(lengths, jnp.int32)


class TestParamPlumbing:
    def test_param_names_match_shapes(self, cfg):
        names = M.param_names(cfg)
        shapes = M.param_shapes(cfg)
        assert set(names) == set(shapes)
        assert len(names) == len(set(names))

    def test_param_count_formula(self, cfg, params):
        assert sum(int(p.size) for p in params) == cfg.param_count()

    def test_classifier_param_count(self):
        c = M.CLASSIFIER
        ps = M.init_params(c, 0)
        assert sum(int(p.size) for p in ps) == c.param_count()

    def test_tier_ordering(self):
        sizes = [M.TIERS[t].param_count() for t in ("small", "medium", "large")]
        assert sizes[0] < sizes[1] < sizes[2]


class TestPrefill:
    def test_kernel_matches_ref(self, cfg, params):
        t, L = toks(cfg, [10, 16])
        lk, kvk = M.lm_prefill(cfg, params, t, L, use_kernels=True)
        lr, kvr = M.lm_prefill(cfg, params, t, L, use_kernels=False)
        np.testing.assert_allclose(lk, lr, rtol=1e-4, atol=1e-4)
        # KV only meaningful for positions < length
        for i, n in enumerate([10, 16]):
            np.testing.assert_allclose(
                np.asarray(kvk)[:, :, i, :, :n],
                np.asarray(kvr)[:, :, i, :, :n], rtol=1e-4, atol=1e-4)

    def test_logits_at_last_valid_position(self, cfg, params):
        # Changing padding tokens must not change the last-position logits.
        t, L = toks(cfg, [8])
        l1, _ = M.lm_prefill(cfg, params, t, L)
        t2 = t.at[0, 12:].set(99)
        l2, _ = M.lm_prefill(cfg, params, t2, L)
        np.testing.assert_allclose(l1, l2, atol=1e-5)

    def test_batch_matches_solo(self, cfg, params):
        t, L = toks(cfg, [9, 13], seed=3)
        lb, kvb = M.lm_prefill(cfg, params, t, L)
        for i in range(2):
            ls, _ = M.lm_prefill(cfg, params, t[i : i + 1], L[i : i + 1])
            np.testing.assert_allclose(lb[i : i + 1], ls, rtol=2e-4, atol=1e-4)


class TestDecodeKVEquivalence:
    def test_decode_continues_prefill(self, cfg, params):
        """Prefill(n) + decode steps == prefill(n+k): the KV-cache contract
        the Rust serving loop depends on."""
        full_len = 12
        split = 8
        rs = np.random.RandomState(5)
        seq = rs.randint(4, cfg.vocab, size=full_len).astype(np.int32)

        # Ground truth: prefill over the first n+k tokens directly.
        t_full = np.zeros((1, cfg.seq_prefill), np.int32)
        t_full[0, :full_len] = seq
        logits_full, _ = M.lm_prefill(
            cfg, params, jnp.asarray(t_full),
            jnp.asarray([full_len], jnp.int32))

        # Serving path: prefill the prompt, then feed tokens one by one.
        t_pre = np.zeros((1, cfg.seq_prefill), np.int32)
        t_pre[0, :split] = seq[:split]
        logits, kv = M.lm_prefill(
            cfg, params, jnp.asarray(t_pre), jnp.asarray([split], jnp.int32))
        for i in range(split, full_len):
            logits, kv = M.lm_decode(
                cfg, params, kv,
                jnp.asarray([seq[i]], jnp.int32),
                jnp.asarray([i], jnp.int32))
        np.testing.assert_allclose(logits, logits_full, rtol=2e-3, atol=2e-3)

    def test_decode_kernel_matches_ref(self, cfg, params):
        t, L = toks(cfg, [6, 11], seed=9)
        _, kv = M.lm_prefill(cfg, params, t, L)
        nt = jnp.asarray([42, 99], jnp.int32)
        lk, kvk = M.lm_decode(cfg, params, kv, nt, L, use_kernels=True)
        lr, kvr = M.lm_decode(cfg, params, kv, nt, L, use_kernels=False)
        np.testing.assert_allclose(lk, lr, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(kvk, kvr, rtol=1e-4, atol=1e-4)

    def test_decode_batch_independent_positions(self, cfg, params):
        """Sequences at different depths decode independently — the
        continuous-batching invariant."""
        t, L = toks(cfg, [5, 14], seed=11)
        _, kv = M.lm_prefill(cfg, params, t, L)
        nt = jnp.asarray([7, 8], jnp.int32)
        lb, _ = M.lm_decode(cfg, params, kv, nt, L)
        for i in range(2):
            ti, Li = toks(cfg, [[5, 14][i]], seed=11)
            # regenerate the same tokens for example i
            t_solo = t[i : i + 1]
            L_solo = L[i : i + 1]
            _, kv_solo = M.lm_prefill(cfg, params, t_solo, L_solo)
            ls, _ = M.lm_decode(cfg, params, kv_solo, nt[i : i + 1], L_solo)
            np.testing.assert_allclose(lb[i : i + 1], ls, rtol=2e-4, atol=2e-4)

    def test_greedy_generation_deterministic(self, cfg, params):
        t, L = toks(cfg, [10], seed=13)
        outs = []
        for _ in range(2):
            logits, kv = M.lm_prefill(cfg, params, t, L)
            cur = int(jnp.argmax(logits[0]))
            gen = [cur]
            pos = 10
            for _ in range(5):
                logits, kv = M.lm_decode(
                    cfg, params, kv, jnp.asarray([cur], jnp.int32),
                    jnp.asarray([pos], jnp.int32))
                cur = int(jnp.argmax(logits[0]))
                gen.append(cur)
                pos += 1
            outs.append(gen)
        assert outs[0] == outs[1]
        assert all(0 <= g < cfg.vocab for g in outs[0])


class TestClassifier:
    def test_kernel_matches_ref_on_real_prompts(self):
        cfg = M.CLASSIFIER
        ps = M.init_params(cfg, 3)
        texts = ["what is 2 plus 2", "prove that f is monotonic",
                 "write a python function that reverses a list"]
        ids = jnp.asarray([tok.encode(t) for t in texts], jnp.int32)
        # batch of 3 → pad to 8 like the serving path does
        ids = jnp.pad(ids, ((0, 5), (0, 0)))
        pk = M.classifier_probs(cfg, ps, ids, use_kernels=True)
        pr = M.classifier_probs(cfg, ps, ids, use_kernels=False)
        np.testing.assert_allclose(pk, pr, rtol=1e-4, atol=1e-5)

    def test_probs_normalized(self):
        cfg = M.CLASSIFIER
        ps = M.init_params(cfg, 4)
        ids = jnp.asarray([tok.encode("hello world")], jnp.int32)
        p = np.asarray(M.classifier_probs(cfg, ps, ids))
        assert p.shape == (1, 3)
        assert abs(p.sum() - 1.0) < 1e-5

    def test_padding_invariance(self):
        # Two encodings of the same text with different trailing PAD counts
        # must classify identically (lengths derive from the PAD mask).
        cfg = M.CLASSIFIER
        ps = M.init_params(cfg, 5)
        ids1 = tok.encode("explain why the sky is blue", tok.SEQ_CLS)
        x1 = jnp.asarray([ids1], jnp.int32)
        p1 = M.classifier_probs(cfg, ps, x1)
        # identical content; PAD region can hold anything the mask excludes?
        # No — PAD must be PAD; instead check batch with another row.
        x2 = jnp.asarray([ids1, tok.encode("something else entirely")],
                         jnp.int32)
        p2 = M.classifier_probs(cfg, ps, x2)
        np.testing.assert_allclose(p1[0], p2[0], rtol=1e-4, atol=1e-5)
