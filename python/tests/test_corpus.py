"""Corpus generator: determinism, counts, class balance, template fidelity."""

from collections import Counter

from compile import corpus
from compile import templates as T


def test_counts_match_table1():
    ps = corpus.generate()
    by_bench = Counter(p.benchmark for p in ps)
    for b in T.BENCHMARKS:
        assert by_bench[b] == T.unique_prompts(b)
    # Paper: 31,019 unique prompts (Table 1 runs / 5 profiles)
    assert len(ps) == sum(T.unique_prompts(b) for b in T.BENCHMARKS)


def test_deterministic():
    a = corpus.generate()
    b = corpus.generate()
    assert [(p.text, p.complexity) for p in a[:500]] == [
        (p.text, p.complexity) for p in b[:500]
    ]


def test_no_unfilled_slots():
    for p in corpus.generate()[:2000]:
        assert "{" not in p.text and "}" not in p.text


def test_all_classes_present_per_split():
    train, val = corpus.train_val_split(corpus.generate())
    for split in (train, val):
        classes = {p.complexity for p in split}
        assert classes == {0, 1, 2}


def test_split_disjoint_and_complete():
    ps = corpus.generate()
    train, val = corpus.train_val_split(ps)
    assert len(train) + len(val) == len(ps)
    assert len(val) == int(len(ps) * 0.1)


def test_splitmix_matches_reference():
    # First outputs of SplitMix64(0) — cross-checked with the Rust impl.
    r = corpus.SplitMix64(0)
    assert r.next_u64() == 0xE220A8397B1DCDAF
    assert r.next_u64() == 0x6E789E6AA1B965F4
    assert r.next_u64() == 0x06C45D188009454F


def test_table1_internal_note():
    # The paper's Table 1 total (163,720) != column sum; we reproduce rows.
    rows = sum(T.TABLE1[b]["runs"] for b in T.BENCHMARKS)
    assert rows == 155_095
