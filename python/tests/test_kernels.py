"""L1 correctness: every Pallas kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes (and the f32 dtype the AOT path uses) per the
repro contract; tolerances are tight because interpret-mode Pallas and
jnp share the same scalar semantics.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# Shape sweeps need hypothesis; offline dev boxes may lack it, so the
# whole module is skipped (not errored) there. CI installs hypothesis and
# runs these for real.
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    attention_decode,
    attention_encoder,
    attention_prefill,
    classifier_head,
    ffn,
    layernorm,
    ref,
)

settings.register_profile("kernels", deadline=None, max_examples=20)
settings.load_profile("kernels")


def rand(key, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32) * scale


def assert_close(a, b, rtol=1e-4, atol=1e-5):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=rtol, atol=atol)


# ---------------------------------------------------------------- layernorm


@given(n=st.integers(1, 96), d=st.sampled_from([8, 64, 96, 128, 256]),
       seed=st.integers(0, 2**31))
def test_layernorm_matches_ref(n, d, seed):
    x = rand(seed, (n, d))
    g = rand(seed + 1, (d,), 0.5) + 1.0
    b = rand(seed + 2, (d,), 0.1)
    assert_close(layernorm(x, g, b), ref.layernorm(x, g, b))


def test_layernorm_normalizes():
    x = rand(0, (32, 64), 5.0) + 3.0
    y = np.asarray(layernorm(x, jnp.ones(64), jnp.zeros(64)))
    assert np.allclose(y.mean(-1), 0.0, atol=1e-4)
    assert np.allclose(y.std(-1), 1.0, atol=1e-2)


def test_layernorm_odd_rows_falls_back_to_divisor_block():
    # 17 rows: block search must find a divisor (here 17 itself → 1 step)
    x = rand(3, (17, 64))
    assert_close(layernorm(x, jnp.ones(64), jnp.zeros(64)),
                 ref.layernorm(x, jnp.ones(64), jnp.zeros(64)))


# ---------------------------------------------------------------------- ffn


@given(n=st.sampled_from([1, 4, 32, 64]),
       d=st.sampled_from([32, 64, 128]),
       f=st.sampled_from([64, 256, 512]),
       seed=st.integers(0, 2**31))
def test_ffn_matches_ref(n, d, f, seed):
    x = rand(seed, (n, d))
    w1 = rand(seed + 1, (d, f), 0.05)
    b1 = rand(seed + 2, (f,), 0.01)
    w2 = rand(seed + 3, (f, d), 0.05)
    b2 = rand(seed + 4, (d,), 0.01)
    assert_close(ffn(x, w1, b1, w2, b2), ref.ffn(x, w1, b1, w2, b2),
                 rtol=1e-4, atol=1e-4)


def test_gelu_reference_values():
    # GeLU(0)=0, GeLU(large)≈large, GeLU(-large)≈0
    x = jnp.array([[0.0, 10.0, -10.0, 1.0]])
    w1 = jnp.eye(4)
    w2 = jnp.eye(4)
    z = np.asarray(ffn(x, w1, jnp.zeros(4), w2, jnp.zeros(4)))
    assert abs(z[0, 0]) < 1e-6
    assert abs(z[0, 1] - 10.0) < 1e-3
    assert abs(z[0, 2]) < 1e-3
    assert abs(z[0, 3] - 0.8412) < 1e-3


# ---------------------------------------------------------- prefill attention


@given(b=st.sampled_from([1, 2, 4]), h=st.sampled_from([1, 2, 4]),
       s=st.sampled_from([8, 16, 64]), dh=st.sampled_from([8, 24, 32]),
       seed=st.integers(0, 2**31))
def test_attention_prefill_matches_ref(b, h, s, dh, seed):
    q = rand(seed, (b, h, s, dh))
    k = rand(seed + 1, (b, h, s, dh))
    v = rand(seed + 2, (b, h, s, dh))
    lengths = jnp.asarray(
        np.random.RandomState(seed % 2**31).randint(1, s + 1, size=b),
        jnp.int32)
    got = attention_prefill(q, k, v, lengths)
    want = ref.attention_prefill(q, k, v, lengths)
    # only positions < length are meaningful per example
    for i in range(b):
        L = int(lengths[i])
        assert_close(got[i, :, :L], want[i, :, :L])


def test_attention_prefill_is_causal():
    # Changing K/V at position j must not affect outputs at positions < j.
    b, h, s, dh = 1, 2, 16, 8
    q = rand(0, (b, h, s, dh))
    k = rand(1, (b, h, s, dh))
    v = rand(2, (b, h, s, dh))
    L = jnp.array([s], jnp.int32)
    base = np.asarray(attention_prefill(q, k, v, L))
    k2 = k.at[:, :, 10].set(99.0)
    v2 = v.at[:, :, 10].set(-99.0)
    pert = np.asarray(attention_prefill(q, k2, v2, L))
    assert np.allclose(base[:, :, :10], pert[:, :, :10], atol=1e-6)
    assert not np.allclose(base[:, :, 10:], pert[:, :, 10:], atol=1e-3)


def test_attention_encoder_sees_future():
    b, h, s, dh = 1, 1, 8, 8
    q = rand(0, (b, h, s, dh))
    k = rand(1, (b, h, s, dh))
    v = rand(2, (b, h, s, dh))
    L = jnp.array([s], jnp.int32)
    base = np.asarray(attention_encoder(q, k, v, L))
    v2 = v.at[:, :, 7].set(50.0)
    pert = np.asarray(attention_encoder(q, k, v2, L))
    # position 0 must change: encoder attention is bidirectional
    assert not np.allclose(base[:, :, 0], pert[:, :, 0], atol=1e-3)
    assert_close(base, ref.attention_encoder(q, k, v, L))


def test_attention_padding_ignored():
    # K/V beyond each example's length must not influence the output.
    b, h, s, dh = 2, 2, 16, 8
    q = rand(0, (b, h, s, dh))
    k = rand(1, (b, h, s, dh))
    v = rand(2, (b, h, s, dh))
    lengths = jnp.array([5, 9], jnp.int32)
    base = np.asarray(attention_prefill(q, k, v, lengths))
    k2 = k.at[0, :, 5:].set(77.0).at[1, :, 9:].set(77.0)
    v2 = v.at[0, :, 5:].set(-77.0).at[1, :, 9:].set(-77.0)
    pert = np.asarray(attention_prefill(q, k2, v2, lengths))
    assert np.allclose(base[0, :, :5], pert[0, :, :5], atol=1e-6)
    assert np.allclose(base[1, :, :9], pert[1, :, :9], atol=1e-6)


# ----------------------------------------------------------- decode attention


@given(b=st.sampled_from([1, 2, 8]), h=st.sampled_from([1, 4]),
       smax=st.sampled_from([16, 96]), dh=st.sampled_from([8, 32]),
       seed=st.integers(0, 2**31))
def test_attention_decode_matches_ref(b, h, smax, dh, seed):
    q = rand(seed, (b, h, dh))
    kc = rand(seed + 1, (b, h, smax, dh))
    vc = rand(seed + 2, (b, h, smax, dh))
    pos = jnp.asarray(
        np.random.RandomState(seed % 2**31).randint(0, smax, size=b),
        jnp.int32)
    assert_close(attention_decode(q, kc, vc, pos),
                 ref.attention_decode(q, kc, vc, pos))


def test_attention_decode_ignores_future_cache():
    b, h, smax, dh = 1, 2, 32, 8
    q = rand(0, (b, h, dh))
    kc = rand(1, (b, h, smax, dh))
    vc = rand(2, (b, h, smax, dh))
    pos = jnp.array([10], jnp.int32)
    base = np.asarray(attention_decode(q, kc, vc, pos))
    kc2 = kc.at[:, :, 11:].set(123.0)
    vc2 = vc.at[:, :, 11:].set(-123.0)
    pert = np.asarray(attention_decode(q, kc2, vc2, pos))
    assert np.allclose(base, pert, atol=1e-6)


def test_attention_decode_per_sequence_positions():
    # Two sequences at different depths in one launch (continuous batching).
    b, h, smax, dh = 2, 1, 16, 8
    q = rand(0, (b, h, dh))
    kc = rand(1, (b, h, smax, dh))
    vc = rand(2, (b, h, smax, dh))
    pos = jnp.array([3, 12], jnp.int32)
    got = np.asarray(attention_decode(q, kc, vc, pos))
    for i in range(b):
        solo = np.asarray(attention_decode(
            q[i : i + 1], kc[i : i + 1], vc[i : i + 1], pos[i : i + 1]))
        assert np.allclose(got[i : i + 1], solo, atol=1e-6)


# ------------------------------------------------------------ classifier head


@given(b=st.sampled_from([1, 8, 32]), d=st.sampled_from([16, 96]),
       c=st.sampled_from([2, 3, 5]), seed=st.integers(0, 2**31))
def test_classifier_head_matches_ref(b, d, c, seed):
    h = rand(seed, (b, d))
    w = rand(seed + 1, (d, c))
    bias = rand(seed + 2, (c,), 0.1)
    got = classifier_head(h, w, bias)
    assert_close(got, ref.classifier_head(h, w, bias), rtol=1e-5, atol=1e-6)
    probs = np.asarray(got)
    assert np.allclose(probs.sum(-1), 1.0, atol=1e-5)
    assert (probs >= 0).all()


# ------------------------------------------------------------- VMEM contract


def test_vmem_budget_enforced():
    from compile.kernels.common import assert_vmem_ok

    with pytest.raises(ValueError):
        assert_vmem_ok("huge", [(4096, 4096)])  # 64 MiB > 16 MiB budget


def test_mxu_utilization_model():
    from compile.kernels.common import mxu_utilization

    assert mxu_utilization(128, 128, 128) == 1.0
    assert mxu_utilization(64, 128, 128) == 0.5
    assert 0 < mxu_utilization(24, 24, 96) < 0.1
