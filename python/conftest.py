"""Make `compile.*` importable however pytest is invoked.

The test-suite imports the AOT pipeline as `from compile import ...`,
which resolves when pytest runs from `python/` but not from the repo
root (the CI invocation is `python -m pytest python/tests -q`). Pin the
package root onto sys.path here so both work.
"""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
