//! Quickstart: load the compiled artifacts, route a few prompts through
//! the hybrid router, and generate completions on the tier Alg. 2 picks.
//!
//! ```text
//! make artifacts && cargo run --release --example quickstart
//! ```

use pick_and_spin::config::Config;
use pick_and_spin::gateway::LiveStack;

fn main() -> anyhow::Result<()> {
    let cfg = Config::default();
    println!("== Pick and Spin quickstart ==");
    println!("loading + compiling artifacts (once; Python never runs at request time)...");
    let t0 = std::time::Instant::now();
    let stack = LiveStack::start(&cfg)?;
    println!("stack ready in {:.1}s\n", t0.elapsed().as_secs_f64());

    let prompts = [
        "What is 7 plus 12?",
        "Natalia sold 48 clips in April and half as many in May. How many in total?",
        "Write a python function that reverses a linked list.",
        "Prove that the sequence defined by f(n) = 3n + 7 is monotonic for all natural numbers n.",
    ];
    for p in prompts {
        let r = stack.complete(p, 12)?;
        println!("prompt: {p}");
        println!(
            "  → complexity {} ({}, conf {:.2}) routed to {} [{} tier]",
            r.complexity,
            ["low", "medium", "high"][r.complexity],
            r.confidence,
            r.model,
            r.tier
        );
        println!(
            "  → {} prompt tokens, {} generated, TTFT {:.1} ms, total {:.1} ms",
            r.prompt_tokens,
            r.tokens.len(),
            r.ttft_s * 1e3,
            r.latency_s * 1e3
        );
        println!("  → token ids: {:?}\n", &r.tokens[..r.tokens.len().min(8)]);
    }

    // The easy prompt must land on a smaller model than the proof.
    let easy = stack.complete(prompts[0], 8)?;
    let hard = stack.complete(prompts[3], 8)?;
    assert!(easy.complexity < hard.complexity, "routing sanity");
    println!("routing sanity holds: easy → tier {}, hard → tier {}", easy.tier, hard.tier);
    stack.shutdown();
    Ok(())
}
