//! End-to-end driver (the repro contract's E2E example): load the real
//! compiled artifacts, serve a mixed 8-benchmark workload of batched
//! requests through router + matrix + PJRT engines, and report
//! latency/throughput.

use pick_and_spin::config::Config;
use pick_and_spin::gateway::{serve_http, LiveStack};
use pick_and_spin::gateway::http::http_request;
use pick_and_spin::util::stats::Summary;
use pick_and_spin::workload::{Generator, TemplateLibrary};
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let cfg = Config::default();
    let lib = TemplateLibrary::load("data/templates.json")?;
    println!("== end-to-end: serve the 8-benchmark mix on the live stack ==");
    let t0 = std::time::Instant::now();
    let stack = Arc::new(LiveStack::start(&cfg)?);
    println!("artifacts compiled + weights resident in {:.1}s", t0.elapsed().as_secs_f64());

    // Also exercise the real HTTP gateway for a few requests.
    let srv = serve_http(Arc::clone(&stack), 0, 4)?;
    let (status, body) = http_request(
        srv.port, "POST", "/v1/completions",
        Some(r#"{"prompt": "what is 2 plus 2?", "max_tokens": 6}"#))?;
    println!("HTTP gateway: status {status}, body: {}…", &body[..body.len().min(100)]);
    assert_eq!(status, 200);

    let n = 60;
    let mut gen = Generator::new(&lib, 11);
    let mut latencies = Vec::new();
    let mut ttfts = Vec::new();
    let mut tokens = 0usize;
    let mut by_tier = std::collections::BTreeMap::new();
    let t1 = std::time::Instant::now();
    for i in 0..n {
        let req = gen.request(i, 0.0);
        let r = stack.complete(&req.prompt, 12)?;
        latencies.push(r.latency_s);
        ttfts.push(r.ttft_s);
        tokens += r.tokens.len();
        *by_tier.entry(r.tier.clone()).or_insert(0usize) += 1;
    }
    let wall = t1.elapsed().as_secs_f64();
    let ls = Summary::of(&latencies);
    let ts = Summary::of(&ttfts);
    println!("\nserved {n} mixed-benchmark requests in {wall:.1}s");
    println!("  throughput:  {:.1} req/s, {:.0} tok/s", n as f64 / wall, tokens as f64 / wall);
    println!("  latency:     p50 {:.1} ms  p95 {:.1} ms  p99 {:.1} ms",
             ls.p50 * 1e3, ls.p95 * 1e3, ls.p99 * 1e3);
    println!("  TTFT:        p50 {:.1} ms  p95 {:.1} ms", ts.p50 * 1e3, ts.p95 * 1e3);
    println!("  tier mix:    {by_tier:?}");
    let (status, metrics) = http_request(srv.port, "GET", "/metrics", None)?;
    assert_eq!(status, 200);
    println!("\n/metrics excerpt:\n{}", metrics.lines().take(4).collect::<Vec<_>>().join("\n"));
    srv.stop();
    Ok(())
}
