//! Sweep the paper's four operator profiles over the same workload and
//! show the accuracy/latency/cost tradeoff each (α, λ, μ) buys.

use pick_and_spin::baselines::SelectionPolicy;
use pick_and_spin::config::Profile;
use pick_and_spin::sim::{Deployment, SimConfig};
use pick_and_spin::util::format_table;
use pick_and_spin::workload::{OracleClassifier, TemplateLibrary};

fn main() -> anyhow::Result<()> {
    let lib = TemplateLibrary::load("data/templates.json")?;
    let mut rows = Vec::new();
    for profile in [Profile::QUALITY, Profile::COST, Profile::SPEED, Profile::BALANCED] {
        let mut sc = SimConfig::defaults();
        sc.profile = profile;
        sc.policy = SelectionPolicy::MultiObjective;
        sc.deployment = Deployment::Dynamic { auto_recovery: false };
        sc.n_requests = 12_000;
        sc.rate_qps = 6.0;
        sc.cluster.nodes = 8;
        let cls = Box::new(OracleClassifier::new(lib.clone(), 0.03, 7));
        let rep = pick_and_spin::sim::run(&sc, &lib, cls)?;
        rows.push(vec![
            format!("{} (α={}, λ={}, μ={})", profile.name,
                    profile.alpha, profile.lambda, profile.mu),
            format!("{:.1}", rep.success_rate() * 100.0),
            format!("{:.1}", rep.mean_latency_s()),
            format!("{:.4}", rep.cost_per_query_usd()),
            format!("{:.1}", rep.gpu_utilization() * 100.0),
        ]);
    }
    println!("== operator profiles over an identical 12k-request workload ==\n");
    println!("{}", format_table(
        &["Profile", "Success (%)", "Latency (s)", "$/query", "GPU util (%)"],
        &rows,
    ));
    println!("quality maximizes success; cost minimizes $/query; speed\nminimizes latency; balanced sits between — the Eq. 2 knobs at work.");
    Ok(())
}
