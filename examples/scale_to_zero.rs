//! Scale-to-zero timeline: bursty demand against the Spin orchestrator,
//! printing held GPUs per phase — the Alg. 1 lifecycle in action.

use pick_and_spin::baselines::SelectionPolicy;
use pick_and_spin::sim::{Deployment, SimConfig};
use pick_and_spin::workload::{OracleClassifier, TemplateLibrary};

fn main() -> anyhow::Result<()> {
    let lib = TemplateLibrary::load("data/templates.json")?;
    println!("== scale-to-zero under bursty demand ==\n");
    for (name, deployment, policy) in [
        ("static (always-on)", Deployment::Static, SelectionPolicy::RoundRobin),
        ("pick-and-spin", Deployment::Dynamic { auto_recovery: false },
         SelectionPolicy::MultiObjective),
    ] {
        let mut sc = SimConfig::defaults();
        sc.deployment = deployment;
        sc.policy = policy;
        sc.n_requests = 10_000;
        sc.bursty = Some((8.0, 0.2, 180.0)); // 3-min bursts, near-idle valleys
        sc.cluster.nodes = 8;
        sc.orchestrator.idle_timeout_s = 45.0;
        sc.static_replicas = 2;
        let cls = Box::new(OracleClassifier::new(lib.clone(), 0.03, 7));
        let rep = pick_and_spin::sim::run(&sc, &lib, cls)?;
        println!(
            "{name:<22} cost/query ${:.4}  GPU-hours {:.1}  success {:.1}%  p95 wait {:.1}s",
            rep.cost_per_query_usd(),
            rep.gpu_seconds_held / 3600.0,
            rep.success_rate() * 100.0,
            pick_and_spin::util::stats::percentile(
                &rep.records.iter().map(|r| r.wait_s).collect::<Vec<_>>(), 95.0),
        );
    }
    println!("\nidle valleys cost the static fleet money; Spin sheds capacity\nafter the idle timeout and re-spins on the next burst (cold starts\nshow up as p95 wait).");
    Ok(())
}
